"""The PS wire format: framing, an array-tree codec, and the numpy side of
the paper's §4.2.3 blockscale value compression.

* Frames are ``MAGIC + u64 length + payload`` on a stream socket — the
  length prefix is the whole protocol, so a half-written frame (a killed
  peer) is detected as a short read, never a parse of garbage.
* The payload codec serializes the same trees the checkpoint blobs hold
  (nested dicts/lists of numpy arrays + scalars): a json header describing
  the structure, then the raw little-endian array buffers concatenated —
  serialisation is a memory copy, exactly the checkpoint's
  manifest+data.bin layout but on a socket.
* ``np_blockscale_compress`` mirrors ``repro.core.compression`` in numpy,
  bit-for-bit (same fp32 scale arithmetic, same fp16 round-to-nearest
  cast), so a remote table behind the lossy wire is numerically identical
  to the in-process :class:`CompressedWireBackend` — tested in
  ``tests/test_net.py``.
"""
from __future__ import annotations

import dataclasses
import json
import socket
import struct

import numpy as np

MAGIC = b"PSR1"
_HEADER = struct.Struct("<4sQ")       # magic + payload length
MAGIC2 = b"PSR2"                      # rid-tagged frames (pipelined RPC)
_HEADER2 = struct.Struct("<4sQQ")     # magic + rid + payload length
MAX_FRAME = 1 << 33                   # 8 GiB sanity bound on one message

KAPPA = 32_768.0                      # keep in sync with core/compression.py


class WireError(ConnectionError):
    """Framing/codec violation (bad magic, truncated frame, unknown node)."""


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------

def send_frame(sock: socket.socket, payload: bytes) -> int:
    header = _HEADER.pack(MAGIC, len(payload))
    sock.sendall(header + payload)
    return len(header) + len(payload)


def recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            raise WireError(f"peer closed mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> bytes:
    magic, length = _HEADER.unpack(recv_exact(sock, _HEADER.size))
    if magic != MAGIC:
        raise WireError(f"bad frame magic {magic!r}")
    if length > MAX_FRAME:
        raise WireError(f"frame length {length} exceeds MAX_FRAME")
    return recv_exact(sock, length)


# ---------------------------------------------------------------------------
# Tagged framing (the pipelined transport): rid in the frame header so the
# receiver demuxes replies without decoding payloads, scatter-gather send
# over the codec's buffer list (no intermediate join), and a reusable
# receive buffer so steady-state traffic allocates nothing per frame.
# ---------------------------------------------------------------------------

def send_frame_parts(sock: socket.socket, rid: int, parts) -> int:
    """Send one rid-tagged frame from a list of buffers via ``sendmsg``
    (scatter-gather — the payload is never joined into one bytes)."""
    views = [memoryview(p).cast("B") for p in parts]
    length = sum(len(v) for v in views)
    if length > MAX_FRAME:
        raise WireError(f"frame length {length} exceeds MAX_FRAME")
    views.insert(0, memoryview(_HEADER2.pack(MAGIC2, rid, length)))
    total = length + _HEADER2.size
    sent = 0
    while sent < total:
        n = sock.sendmsg(views)
        if n <= 0:
            raise WireError("sendmsg made no progress")
        sent += n
        if sent >= total:
            break
        # drop fully-sent buffers, slice the partially-sent one
        while n > 0:
            if n >= len(views[0]):
                n -= len(views[0])
                views.pop(0)
            else:
                views[0] = views[0][n:]
                n = 0
    return total


class RecvBuffer:
    """A growable receive buffer one connection reuses across frames —
    ``recv_frame_tagged`` reads payloads into it with ``recv_into`` (no
    per-frame allocation once warm). ``decode`` copies arrays out, so the
    returned view only has to live until the next recv."""

    def __init__(self, initial: int = 1 << 16):
        self._buf = bytearray(initial)

    def view(self, n: int) -> memoryview:
        if len(self._buf) < n:
            self._buf = bytearray(max(n, 2 * len(self._buf)))
        return memoryview(self._buf)[:n]


def recv_into_exact(sock: socket.socket, view: memoryview):
    got = 0
    n = len(view)
    while got < n:
        r = sock.recv_into(view[got:])
        if not r:
            raise WireError(f"peer closed mid-frame ({got}/{n} bytes)")
        got += r


def recv_frame_tagged(sock: socket.socket,
                      buf: RecvBuffer) -> tuple[int, memoryview]:
    """Read one rid-tagged frame into ``buf``; returns ``(rid, payload)``.
    The payload view aliases the reusable buffer — decode (which copies
    arrays out) before the next read."""
    magic, rid, length = _HEADER2.unpack(recv_exact(sock, _HEADER2.size))
    if magic != MAGIC2:
        raise WireError(f"bad frame magic {magic!r}")
    if length > MAX_FRAME:
        raise WireError(f"frame length {length} exceeds MAX_FRAME")
    view = buf.view(int(length))
    recv_into_exact(sock, view)
    return int(rid), view


# ---------------------------------------------------------------------------
# Array-tree codec
# ---------------------------------------------------------------------------

def _enc_node(node, bufs: list):
    if node is None or isinstance(node, (bool, int, float, str)):
        return node if not isinstance(node, bool) else {"t": "b", "v": node}
    if isinstance(node, dict):
        return {"t": "d", "v": {str(k): _enc_node(v, bufs)
                                for k, v in node.items()}}
    if isinstance(node, (list, tuple)):
        return {"t": "l" if isinstance(node, list) else "t",
                "v": [_enc_node(v, bufs) for v in node]}
    a = np.asarray(node)
    if a.dtype == object:
        raise WireError(f"cannot encode object array {node!r}")
    # memoryview over the array's own buffer — no tobytes() copy; the view
    # keeps the (contiguous) array alive for as long as the parts list does
    # (cast rejects zero-size shapes, so empty arrays ship an empty buffer)
    raw = (memoryview(np.ascontiguousarray(a)).cast("B") if a.size
           else memoryview(b""))
    bufs.append(raw)
    return {"t": "a", "d": str(a.dtype), "s": list(a.shape), "n": len(raw)}


def _dec_node(node, bufs: list[memoryview], pos: list[int]):
    if not isinstance(node, dict):
        return node
    t = node["t"]
    if t == "b":
        return bool(node["v"])
    if t == "d":
        return {k: _dec_node(v, bufs, pos) for k, v in node["v"].items()}
    if t in ("l", "t"):
        seq = [_dec_node(v, bufs, pos) for v in node["v"]]
        return seq if t == "l" else tuple(seq)
    if t == "a":
        raw = bufs[pos[0]]
        pos[0] += 1
        arr = np.frombuffer(raw, dtype=node["d"]).reshape(node["s"])
        return arr.copy()      # decouple from the receive buffer
    raise WireError(f"unknown wire node tag {t!r}")


def encode_parts(tree) -> list:
    """Tree -> list of payload buffers (header + raw array views, never
    joined). Feed to :func:`send_frame_parts` for a scatter-gather send,
    or ``b"".join(...)`` for the legacy one-bytes payload."""
    bufs: list = []
    header = json.dumps(_enc_node(tree, bufs),
                        separators=(",", ":")).encode()
    parts = [struct.pack("<I", len(header)), header]
    parts.extend(bufs)
    return parts


def encode(tree) -> bytes:
    """Tree of dicts/lists/scalars/arrays -> one bytes payload."""
    return b"".join(encode_parts(tree))


def decode(payload):
    """Inverse of :func:`encode`; accepts bytes or a memoryview (the
    tagged-frame receive path decodes straight out of the reusable
    receive buffer — arrays are copied out, so the view may be reused)."""
    (hlen,) = struct.unpack_from("<I", payload, 0)
    header = json.loads(bytes(payload[4: 4 + hlen]))
    view = memoryview(payload)
    bufs: list[memoryview] = []
    off = 4 + hlen

    def _collect(node):
        nonlocal off
        if isinstance(node, dict):
            if node.get("t") == "a":
                bufs.append(view[off: off + node["n"]])
                off += node["n"]
            elif node.get("t") == "d":
                for v in node["v"].values():
                    _collect(v)
            elif node.get("t") in ("l", "t"):
                for v in node["v"]:
                    _collect(v)
    _collect(header)
    return _dec_node(header, bufs, [0])


def tree_nbytes(tree) -> int:
    """Array payload bytes of a tree (codec framing/header excluded) — the
    honest bytes-on-wire gauge the benchmarks report."""
    total = 0
    for leaf in _iter_leaves(tree):
        total += np.asarray(leaf).nbytes
    return total


def _iter_leaves(tree):
    if isinstance(tree, dict):
        for v in tree.values():
            yield from _iter_leaves(v)
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            yield from _iter_leaves(v)
    elif tree is not None and not isinstance(tree, (bool, int, float, str)):
        yield tree


# ---------------------------------------------------------------------------
# Blockscale fp16 values on the wire (numpy mirror of core/compression.py)
# ---------------------------------------------------------------------------

def np_blockscale_compress(v: np.ndarray, block: int = 128):
    """fp32 array -> (fp16 blocks, fp32 per-block scales, orig shape).
    Same arithmetic as the jnp reference: linf per block, scale =
    KAPPA / max(linf, 1e-30) in fp32, fp16 cast (round-to-nearest-even in
    both numpy and XLA), so the roundtrip is bit-identical."""
    v = np.asarray(v, np.float32)
    orig_shape = v.shape
    flat = v.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.float32)])
    blocks = flat.reshape(-1, block)
    linf = np.max(np.abs(blocks), axis=-1, keepdims=True)
    scale = (np.float32(KAPPA) / np.maximum(linf, np.float32(1e-30))) \
        .astype(np.float32)
    comp = (blocks * scale).astype(np.float16)
    return comp, scale[:, 0], orig_shape


def np_blockscale_decompress(comp, scale, orig_shape):
    blocks = comp.astype(np.float32) / np.asarray(scale, np.float32)[:, None]
    n = 1
    for s in orig_shape:
        n *= int(s)
    return blocks.reshape(-1)[:n].reshape(orig_shape)


def lossy_pack(v: np.ndarray, block: int = 128) -> dict:
    """Value payload for the lossy wire: fp16 blocks + fp32 scales."""
    comp, scale, shape = np_blockscale_compress(v, block)
    return {"__bs__": 1, "c": comp, "s": scale,
            "shape": [int(x) for x in shape]}


def lossy_unpack(payload) -> np.ndarray:
    """Inverse of :func:`lossy_pack`; raw fp32 arrays pass through."""
    if isinstance(payload, dict) and payload.get("__bs__"):
        return np_blockscale_decompress(payload["c"], payload["s"],
                                        tuple(payload["shape"]))
    return np.asarray(payload, np.float32)


def payload_nbytes(payload) -> int:
    if isinstance(payload, dict) and payload.get("__bs__"):
        return int(np.asarray(payload["c"]).nbytes
                   + np.asarray(payload["s"]).nbytes)
    return int(np.asarray(payload).nbytes)


# ---------------------------------------------------------------------------
# EmbeddingSpec <-> wire dict (all-primitive; dtype travels as its name)
# ---------------------------------------------------------------------------

def spec_to_dict(spec) -> dict:
    d = dataclasses.asdict(spec)
    d["dtype"] = np.dtype(d["dtype"]).name
    return d


def spec_from_dict(d: dict):
    from repro.core.embedding_ps import EmbeddingSpec
    d = dict(d)
    d["dtype"] = np.dtype(d["dtype"])
    return EmbeddingSpec(**d)
