"""Client-side remote embedding backends: the ``EmbeddingBackend``
protocol over RPC, so ``PersiaTrainer`` / ``PipelinedTrainer`` train
against PS *processes* unchanged.

How the traceable ops cross the process boundary
------------------------------------------------
``lookup`` runs as a :func:`jax.pure_callback` and puts as an *ordered*
:func:`jax.experimental.io_callback` — the RPCs happen on the host while
the program stays one jitted dispatch. The table's device state shrinks to
a single int32 **version scalar**: every put returns ``version + 1`` and
every lookup consumes the version, so the data dependency forces
put-before-lookup ordering across JAX's async dispatch — the same
happens-before edge the in-process backends get from threading the table
arrays themselves. ``prepare``/checkpoint paths block on the version
(``np.asarray``) before their own RPC.

The wire path is **pipelined** (see :mod:`repro.net.rpc`): a put does not
wait for its ack — it is buffered into the connection's coalescing buffer
and acknowledged asynchronously, bounded by a per-table **outstanding-ack
window** (sync tables window 1; hybrid windows capped at the staleness
bound tau, so the at-risk in-flight updates never exceed what the paper's
bounded-staleness protocol already tolerates). Ordering no longer comes
from draining: the server executes every op on a connection serially in
arrival order, and the version-scalar barrier guarantees the put was
*buffered* before the next prepare/lookup is, so puts always apply first
— bit-exactness without a single blocking round-trip on the step path.
``sync(state)`` drains the table's window (flush + wait every outstanding
ack); ``prepare`` only takes the version barrier and rides the same
coalesced frame as the buffered puts (put for step t + prepare for step
t+1 arrive as ONE ``step_ops`` frame per endpoint). Endpoint connections
are shared through a refcounted client pool, so a k-table trainer
coalesces cross-table ops into O(shards) frames per step instead of
O(tables x shards x phases).

Numerics
--------
The server hosts the *same* dense/host_lru backend this process would, and
runs the identical eager ops — so training over ``RemoteBackend`` with the
raw fp32 wire is bit-exact with the in-process backend. With
``lossy=True`` the wire carries blockscale-fp16 payloads (get activations
and put gradients — never reshard/seed rows), compressed at exactly the
points :class:`CompressedWireBackend` compresses, with a numpy codec that
matches the jnp reference bit-for-bit: a single-endpoint remote+lossy
table is bit-exact with in-process ``+compressed``. (Sharded lossy tables
compress per shard — the in-process wire compresses at the router, so
block boundaries differ there: same algorithm, not the same bits.)

Sharding
--------
:class:`RemoteShardedBackend` subclasses the in-process
:class:`ShardedBackend` router and only swaps the per-shard sub-backend
factory for RPC endpoints — routing, concurrent per-shard prepare,
shard-encoded device ids, shard-tagged checkpoints and the N->M reshard
machinery are all inherited. ``reshard_live`` reuses that reshard path
against *live* members for elastic leave/join (repro.net.elastic).

Staleness queues live server-side (they are PS state, per the paper); the
client threads a zero-byte ``(tau, 0)`` placeholder through the trainer so
queue-depth validation and checkpoint plumbing stay unchanged. A remote
checkpoint therefore snapshots applied state only — pending queued puts
are dropped on save, the same tolerated in-flight loss as a reshard.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from concurrent.futures import Future

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback

from repro.core import backend as BK
from repro.core import compression as C
from repro.core import dedup as D
from repro.core.backend import EmbeddingBackend, ShardedBackend, _prod
from repro.core.embedding_ps import EmbeddingSpec
from repro.net import wire
from repro.net.rpc import PSUnavailableError, RpcClient, RpcError

_SCALAR_F32 = jax.ShapeDtypeStruct((), jnp.float32)
_SCALAR_I32 = jax.ShapeDtypeStruct((), jnp.int32)
_PUT_OUT = (_SCALAR_I32, _SCALAR_F32, _SCALAR_F32)

DEFAULT_MAX_PUT_WINDOW = 8     # hybrid ack-window cap (min'd with tau)
_AUX_WINDOW = 64               # outstanding pin/unpin acks before reaping

# ---------------------------------------------------------------------------
# Shared per-endpoint clients: every table/shard talking to the same PS
# process multiplexes ONE pipelined connection, so coalesced ops from all
# of a trainer's tables ride the same step_ops frame. Refcounted so a
# reshard (which closes and rebuilds sub-backends) tears the connection
# down only when its last user is gone.
# ---------------------------------------------------------------------------

_POOL_LOCK = threading.Lock()
_CLIENT_POOL: dict[tuple, list] = {}     # endpoint -> [client, refcount]


def _acquire_client(endpoint, timeout: float, retries: int,
                    backoff: float) -> RpcClient:
    ep = (str(endpoint[0]), int(endpoint[1]))
    with _POOL_LOCK:
        ent = _CLIENT_POOL.get(ep)
        if ent is None or ent[0]._closing:
            ent = [RpcClient(ep[0], ep[1], timeout=timeout,
                             retries=retries, backoff=backoff), 0]
            _CLIENT_POOL[ep] = ent
        ent[1] += 1
        return ent[0]


def _release_client(client: RpcClient):
    with _POOL_LOCK:
        ep = (client.host, client.port)
        ent = _CLIENT_POOL.get(ep)
        if ent is None or ent[0] is not client:
            client.close()
            return
        ent[1] -= 1
        if ent[1] <= 0:
            del _CLIENT_POOL[ep]
            client.close()


class RemoteBackend(EmbeddingBackend):
    """One table (or one shard of a table) behind a PS process."""

    def __init__(self, spec: EmbeddingSpec, endpoint, table: str = "t",
                 lossy: bool = False, client: RpcClient | None = None,
                 timeout: float = 30.0, retries: int = 3,
                 backoff: float = 0.2, configure: bool = True,
                 put_window: int | None = None, pipelined: bool = True):
        base, wrap = BK.parse_backend_name(spec.backend)
        if wrap:
            raise ValueError(
                "RemoteBackend compresses on the wire itself: pass "
                "lossy=True instead of a '+compressed' backend suffix")
        if int(spec.emb_shards) != 1:
            raise ValueError(
                "RemoteBackend is one PS shard; shard via "
                "RemoteShardedBackend over multiple endpoints")
        if base.startswith("host_lru") and spec.cache_rows <= 0:
            raise ValueError(
                "host_lru backend needs EmbeddingSpec.cache_rows > 0 "
                f"(got {spec.cache_rows})")
        self.spec = spec
        self._base = base
        self.requires_prepare = base.startswith("host_lru")
        self.cache_rows = int(spec.cache_rows)
        # mirror the PS-side slot-pool size (main cache + admission bypass
        # region): device ids returned by the remote prepare live in
        # [0, dev_slots), not [0, cache_rows)
        bypass = ((int(spec.bypass_rows) or max(1, self.cache_rows // 4))
                  if spec.admit_threshold > 0 else 0)
        self.dev_slots = self.cache_rows + bypass
        self._lossy = bool(lossy)
        self._block = int(spec.wire_block)
        self._table = str(table)
        if client is not None:
            self._client, self._owns_client = client, True
        else:
            self._client = _acquire_client(endpoint, timeout, retries,
                                           backoff)
            self._owns_client = False
        # outstanding-ack window: sync tables 1 (one unacked put at most);
        # hybrid tables up to tau (in-flight loss stays within the paper's
        # bounded-staleness tolerance), capped at DEFAULT_MAX_PUT_WINDOW
        self._pipelined = bool(pipelined)
        if put_window is None:
            tau = int(spec.staleness)
            put_window = 1 if tau <= 0 else max(
                1, min(tau, DEFAULT_MAX_PUT_WINDOW))
        if not self._pipelined:
            put_window = 1      # blocking baseline: one synchronous RTT/op
        self.put_window = max(1, int(put_window))
        self._acks: deque = deque()       # outstanding put-ack futures
        self._aux: deque = deque()        # outstanding pin/unpin acks
        self.faults = 0           # host_lru fault/hit mirrors (shard gauges)
        self.hits = 0
        self._queue_width_cfg = 0
        if configure:
            self._call("configure", _mutating=True,
                       spec=wire.spec_to_dict(spec), lossy=self._lossy)

    # -- plumbing ------------------------------------------------------------

    @property
    def endpoint(self):
        return self._client.endpoint

    def _call(self, op: str, _mutating: bool = False, **kw):
        return self._client.call(op, _mutating=_mutating, table=self._table,
                                 **kw)

    def _coal(self, op: str, _mutating: bool = False, **kw):
        if not self._pipelined:
            # blocking-baseline preset (the benchmark's comparison bar):
            # every op is its own synchronous round-trip, no coalescing —
            # the pre-pipelining wire path, behind the same interface
            fut: Future = Future()
            try:
                fut.set_result(self._call(op, _mutating=_mutating, **kw))
            except Exception as e:              # noqa: BLE001
                fut.set_exception(e)
            return fut
        return self._client.coalesce(op, _mutating=_mutating,
                                     table=self._table, **kw)

    def close(self):
        self.discard_pending()
        if self._owns_client:
            self._client.close()
        else:
            _release_client(self._client)

    def discard_pending(self):
        """Drop outstanding ack futures without raising — the membership
        -change path: unacked in-flight puts on a dead shard are the
        paper's tolerated loss, not an error to surface."""
        self._acks.clear()
        self._aux.clear()

    def _fresh_state(self):
        return {"version": jnp.zeros((), jnp.int32)}

    def _barrier(self, state):
        """Wait until every put dispatched against ``state`` has executed
        its io_callback, i.e. is *buffered on this connection* (the
        version scalar is the last put's output). Anything sent after this
        is applied after those puts — the server runs a connection
        serially in arrival order — so ordering needs no ack drain."""
        np.asarray(state["version"])

    def _reap(self, q: deque, limit: int):
        """Pop completed futures (raising their errors) and block the
        window down to ``limit`` outstanding."""
        while q and q[0].done():
            err = q.popleft().exception()
            if err is not None:
                raise err
        while len(q) > limit:
            self._client.flush()            # oldest may still be buffered
            err = None
            try:
                self._client.result(q[0])
            except Exception as e:          # noqa: BLE001
                err = e
            q.popleft()
            if err is not None:
                raise err

    def sync(self, state):
        """Drain this table's window: block until every put dispatched
        against ``state`` has been ACKed by the PS."""
        self._barrier(state)
        self._reap(self._acks, 0)
        self._reap(self._aux, 0)
        return state

    def _dev_rows(self) -> int:
        return (self.dev_slots if self._base.startswith("host_lru")
                else self.spec.rows)

    # -- host-level ----------------------------------------------------------

    def init(self, key, shards: int = 1, scale: float = 0.02):
        if shards != 1:
            raise ValueError(
                "RemoteBackend is one PS shard; shard via "
                f"RemoteShardedBackend (got shards={shards})")
        self._call("init", _mutating=True, key=np.asarray(key),
                   scale=float(scale))
        return self._fresh_state()

    def seed_rows(self, ids, vecs, accs=None):
        """Seed this shard's local rows (router init / reshard path)."""
        self._call("seed_rows", _mutating=True,
                   ids=np.asarray(ids, np.int64),
                   vecs=np.asarray(vecs, np.float32),
                   accs=None if accs is None
                   else np.asarray(accs, np.float32))
        return self._fresh_state()

    def prepare(self, state, ids, assume_unique: bool = False, counts=None):
        return self.prepare_submit(state, ids, assume_unique, counts)()

    def prepare_submit(self, state, ids, assume_unique: bool = False,
                       counts=None):
        """Buffer the prepare into the connection's coalescing buffer (it
        rides the same ``step_ops`` frame as the buffered puts) and return
        a thunk that collects ``(state, dev_ids)``. No drain: the version
        barrier plus the server's serial per-connection execution order the
        fault-in after every put dispatched against ``state``."""
        if not self.requires_prepare:
            return lambda: (state, ids)   # dense: ids ARE device ids
        self._barrier(state)              # puts buffered before prepare is
        self._reap(self._acks, self.put_window)   # surface deferred errors
        fut = self._coal("prepare", ids=np.asarray(ids, np.int64),
                         assume_unique=bool(assume_unique))

        def collect():
            self._client.flush()
            rep = self._client.result(fut)
            self.faults, self.hits = int(rep["faults"]), int(rep["hits"])
            return state, jnp.asarray(rep["dev"], jnp.int32)
        return collect

    def read_rows(self, state, ids):
        """Serve-path read as ONE RPC, executed atomically under the
        server's lock — no prepare/lookup pair for a concurrent trainer
        fault-in to interleave with. Takes the version barrier first (the
        direct call flushes the coalescing buffer), so the serial server
        applies every put dispatched against ``state`` before the read."""
        self._barrier(state)
        arr = np.asarray(ids, np.int64)
        rep = self._call("read_rows", ids=arr)
        acts = wire.lossy_unpack(rep["acts"]).astype(np.float32, copy=False)
        return (acts.reshape(arr.shape + (self.spec.dim,)),
                {"reads": int(rep["reads"]), "hits": int(rep["hits"]),
                 "misses": int(rep["misses"])})

    def dedup_rows(self) -> int:
        return min(self.spec.rows, self._dev_rows())

    def queue_width(self, n_occ: int) -> int:
        if self._lossy:
            # the lossy wire ALWAYS dedups its puts (CompressedWireBackend's
            # pre-dedup width rule, mirrored so queue widths agree)
            return D.dedup_cap(int(n_occ), self._dev_rows())
        return super().queue_width(n_occ)

    def queue_init(self, ids_shape):
        if self.spec.staleness <= 0:
            return None
        return self._queue_init_width(self.queue_width(_prod(ids_shape)))

    def _queue_init_width(self, width: int):
        # width 0 = "re-derive" (a resharded restore of the zero-byte
        # placeholder): fall back to the last configured width; the server
        # also re-creates its queue lazily at the first put's width
        width = int(width) or self._queue_width_cfg
        self._queue_width_cfg = int(width)
        self._call("queue_init", _mutating=True, width=int(width))
        # client-side placeholder: depth tau (so restore validation holds),
        # zero bytes (the real FIFO is PS-side state)
        return {"ids": jnp.zeros((self.spec.staleness, 0), jnp.int32)}

    def pin_slots(self, dev_ids):
        if self.requires_prepare:
            self._reap(self._aux, _AUX_WINDOW)
            self._aux.append(self._coal(
                "pin", _mutating=True,
                slots=np.asarray(dev_ids, np.int64).reshape(-1)))

    def unpin_slots(self, dev_ids):
        if self.requires_prepare:
            self._reap(self._aux, _AUX_WINDOW)
            self._aux.append(self._coal(
                "unpin", _mutating=True,
                slots=np.asarray(dev_ids, np.int64).reshape(-1)))

    def reset_pins(self):
        if self.requires_prepare:
            self._reap(self._aux, _AUX_WINDOW)
            self._aux.append(self._coal("reset_pins", _mutating=True))

    # -- checkpoint / reshard --------------------------------------------------

    def state_for_checkpoint(self, state):
        self.sync(state)
        return self._call("checkpoint")["blob"]

    def restore_from_checkpoint(self, blob):
        rep = self._call("restore", _mutating=True, blob=blob)
        self.last_restore_resharded = bool(rep["resharded"])
        return self._fresh_state()

    def export_logical(self):
        """(vec, acc) of this shard's local rows — always raw fp32 (the
        reshard path must not quantize)."""
        rep = self._call("export_logical")
        acc = rep["acc"]
        return (np.asarray(rep["vec"], np.float32),
                None if acc is None else np.asarray(acc, np.float32))

    def remote_metrics(self) -> dict:
        return self._call("metrics")

    def host_bytes(self) -> int:
        return 0      # the PS process owns the host tier, not this client

    # -- traceable: lookup -----------------------------------------------------

    def _lookup_host(self, version, dev):
        del version                       # ordering operand only
        dev = np.asarray(dev, np.int32)
        rep = self._call("lookup", dev=dev)
        acts = wire.lossy_unpack(rep["acts"]).astype(np.float32, copy=False)
        acts = acts.reshape(dev.shape + (self.spec.dim,))
        wire_b = dev.nbytes + wire.payload_nbytes(rep["acts"])
        return acts, np.float32(wire_b), np.float32(dev.nbytes + acts.nbytes)

    def _lookup_flat(self, state, dev_ids):
        shape = tuple(dev_ids.shape)
        out = (jax.ShapeDtypeStruct(shape + (self.spec.dim,), jnp.float32),
               _SCALAR_F32, _SCALAR_F32)
        acts, bw, br = jax.pure_callback(self._lookup_host, out,
                                         state["version"], dev_ids)
        return acts, {"get_bytes_wire": bw, "get_bytes_raw": br}

    # -- traceable: puts -------------------------------------------------------

    def _grads_payload(self, g: np.ndarray):
        if self._lossy:
            return wire.lossy_pack(g, self._block)
        return g

    def _put_host(self, op: str, unique: bool, version, dev, g):
        """Windowed async put: buffer the op (coalesced into the next
        ``step_ops`` frame) and return immediately — the ack resolves in
        the io thread. At most ``put_window`` acks stay outstanding; a
        full window blocks on (and re-raises errors from) the oldest."""
        dev = np.asarray(dev, np.int32)
        g = np.asarray(g, np.float32)
        payload = self._grads_payload(g)
        self._reap(self._acks, self.put_window - 1)
        self._acks.append(self._coal(op, _mutating=True, dev=dev,
                                     grads=payload, unique=unique))
        wire_b = dev.nbytes + wire.payload_nbytes(payload)
        return (np.int32(np.asarray(version) + 1), np.float32(wire_b),
                np.float32(dev.nbytes + g.nbytes))

    def _put_cb(self, op: str, unique: bool, state, dev, g):
        def host(version, dev_, g_):
            return self._put_host(op, unique, version, dev_, g_)
        ver, bw, br = io_callback(host, _PUT_OUT, state["version"], dev, g,
                                  ordered=True)
        return ({"version": ver},
                {"put_bytes_wire": bw, "put_bytes_raw": br})

    def _put_flat(self, state, dev_ids, grads):
        spec = self.spec
        flat = dev_ids.reshape(-1)
        g = grads.reshape(-1, spec.dim)
        if self._lossy:
            # mirror CompressedWireBackend._compress_put's legacy path:
            # the wire dedups before it compresses
            cap = D.dedup_cap(int(flat.shape[0]), self._dev_rows())
            uniq, g_u = C.dedup_put(flat.astype(jnp.int32),
                                    g.astype(jnp.float32), cap)
            return self._put_unique(state, uniq, g_u)
        return self._put_cb("put", False, state, flat, g)

    def _put_unique(self, state, dev_u, g_u):
        return self._put_cb("put", True, state, dev_u, g_u)

    def _hybrid_flat(self, state, queue, dev_ids, grads):
        spec = self.spec
        flat = dev_ids.reshape(-1)
        g = grads.reshape(-1, spec.dim)
        if self._lossy:
            cap = D.dedup_cap(int(flat.shape[0]), self._dev_rows())
            uniq, g_u = C.dedup_put(flat.astype(jnp.int32),
                                    g.astype(jnp.float32), cap)
            return self._hybrid_unique(state, queue, uniq, g_u)
        st, m = self._put_cb("hybrid", False, state, flat, g)
        return st, queue, m

    def _hybrid_unique(self, state, queue, dev_u, g_u):
        st, m = self._put_cb("hybrid", True, state, dev_u, g_u)
        return st, queue, m


class RemoteShardedBackend(ShardedBackend):
    """The in-process sharded router with every shard behind an RPC
    endpoint: routing, concurrent per-shard prepare, shard-encoded device
    ids, shard-tagged checkpoints and N->M restore resharding are all
    inherited — only the sub-backend factory changes. Adds
    :meth:`reshard_live` (elastic leave/join: redistribute logical rows
    over a new member set mid-run) on top."""

    min_shards = 1       # one PS process is still a remote deployment

    def __init__(self, spec: EmbeddingSpec, endpoints, lossy: bool = False,
                 table: str = "t", timeout: float = 30.0, retries: int = 3,
                 backoff: float = 0.2, put_window: int | None = None,
                 pipelined: bool = True):
        self._endpoints = [tuple(e) for e in endpoints]
        if not self._endpoints:
            raise ValueError("RemoteShardedBackend needs >= 1 endpoint")
        self._lossy = bool(lossy)
        self._table = str(table)
        self._rpc_opts = {"timeout": timeout, "retries": retries,
                          "backoff": backoff, "put_window": put_window,
                          "pipelined": pipelined}
        self._queue_width_cfg = 0
        self.last_reshard_lost_rows = 0
        super().__init__(dataclasses.replace(
            spec, emb_shards=len(self._endpoints)))

    def _make_sub(self, s: int, sub_spec: EmbeddingSpec) -> RemoteBackend:
        return RemoteBackend(sub_spec, self._endpoints[s], table=self._table,
                             lossy=self._lossy, **self._rpc_opts)

    def _configure(self, k: int):
        if k != len(self._endpoints):
            raise ValueError(
                f"RemoteShardedBackend has {len(self._endpoints)} endpoints "
                f"but was asked for {k} shards; change membership via "
                "reshard_live(new_endpoints)")
        for sub in getattr(self, "shard_backends", ()):
            sub.close()
        super()._configure(k)

    def close(self):
        for sub in self.shard_backends:
            sub.close()
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None

    def sync(self, state):
        # flush every shard's buffer first so the ack waits overlap across
        # shards instead of paying one serial round-trip each
        for sub in self.shard_backends:
            sub._client.flush()
        for s, sub in enumerate(self.shard_backends):
            sub.sync(state[f"s{s}"])
        return state

    def discard_pending(self):
        """Drop every shard's outstanding ack futures (membership change:
        in-flight unacked puts are the tolerated loss, not an error)."""
        for sub in self.shard_backends:
            sub.discard_pending()

    # -- seeding / queues over RPC ---------------------------------------------

    def _sub_states_from_logical(self, vec, acc):
        r = self._routing
        ids = np.arange(self.spec.rows)
        own, loc = r.shard_and_local(ids)

        def seed(s):
            sel = own == s
            return self.shard_backends[s].seed_rows(
                loc[sel], np.asarray(vec[sel], np.float32),
                None if acc is None else np.asarray(acc[sel], np.float32))

        pool = self._ensure_pool()
        futs = [pool.submit(seed, s) for s in range(self.n_shards)]
        return {f"s{s}": f.result() for s, f in enumerate(futs)}

    def _queue_init_width(self, width: int):
        width = int(width) or self._queue_width_cfg
        self._queue_width_cfg = int(width)
        return super()._queue_init_width(width)

    # -- elastic membership (repro.net.elastic drives this) --------------------

    def export_all_logical(self, dead_blobs: dict | None = None):
        """Gather the full logical table from live members (concurrently)
        plus spooled blobs for dead ones. A dead shard with no blob loses
        its rows (zero-reinit, counted in ``last_reshard_lost_rows``)."""
        dead_blobs = dead_blobs or {}
        r = self._routing
        ids = np.arange(self.spec.rows)
        own, loc = r.shard_and_local(ids)
        vec = np.zeros((self.spec.rows, self.spec.dim), np.float32)
        acc = (np.zeros((self.spec.rows,), np.float32)
               if self.spec.optimizer == "adagrad" else None)

        def export(s):
            blob = dead_blobs.get(s)
            if blob is not None:
                return BK.extract_logical_rows(
                    blob, self.shard_backends[s].spec, self._base)
            return self.shard_backends[s].export_logical()

        pool = self._ensure_pool()
        futs = [pool.submit(export, s) for s in range(self.n_shards)]
        lost = 0
        for s, f in enumerate(futs):
            sel = own == s
            try:
                v_s, a_s = f.result()
            except (PSUnavailableError, RpcError, OSError):
                lost += int(sel.sum())
                continue
            vec[sel] = v_s[loc[sel]]
            if acc is not None and a_s is not None:
                acc[sel] = a_s[loc[sel]]
        self.last_reshard_lost_rows = lost
        return vec, acc

    def reshard_live(self, endpoints, dead_blobs: dict | None = None):
        """Live N->M reshard onto ``endpoints``: export every logical row
        (survivors via RPC, dead members via their spool blobs), rebuild
        the router over the new member set, and seed each new shard.
        Returns ``(emb_state, emb_queue)`` for the table — queues restart
        empty (pending puts are addressed in the old geometry: the same
        tolerated in-flight loss as a resharded checkpoint restore)."""
        vec, acc = self.export_all_logical(dead_blobs)
        self._endpoints = [tuple(e) for e in endpoints]
        self._configure(len(self._endpoints))
        state = self._sub_states_from_logical(vec, acc)
        queue = None
        if self.spec.staleness > 0:
            # width 0 = unknown (restored placeholder): the RPC still resets
            # the PS queues and the servers re-create them lazily at the
            # next put's width
            queue = self._queue_init_width(self._queue_width_cfg)
        return state, queue


def connect_remote_backends(trainer, endpoints, lossy: bool | None = None,
                            timeout: float = 30.0, retries: int = 3,
                            backoff: float = 0.2,
                            put_window: int | None = None,
                            pipelined: bool = True) -> dict:
    """Point every table of a built ``PersiaTrainer`` at remote PS members.

    Call AFTER constructing the trainer and BEFORE ``init``/``restore``.
    With one endpoint each table gets a plain :class:`RemoteBackend`
    (device ids and the lossy wire then mirror the in-process plain /
    ``+compressed`` backends exactly); with several, a
    :class:`RemoteShardedBackend` over all of them. ``lossy=None``
    derives the wire from each spec's own ``+compressed`` suffix; an
    explicit bool overrides every table. Returns the new backends dict
    (also installed on the trainer, with its jit caches invalidated)."""
    endpoints = [tuple(e) for e in endpoints]
    for name, spec in trainer.collection.items():
        base, wrap = BK.parse_backend_name(spec.backend)
        if spec.emb_shards > 1 and spec.emb_shards != len(endpoints):
            raise ValueError(
                f"table {name!r} declares emb_shards={spec.emb_shards} but "
                f"{len(endpoints)} PS endpoints were given — the remote "
                "shard count IS the member count")
        use_lossy = wrap if lossy is None else bool(lossy)
        sub = dataclasses.replace(spec, backend=base, emb_shards=1)
        old = trainer.backends.get(name)
        if old is not None and hasattr(old, "close"):
            old.close()
        if len(endpoints) == 1:
            trainer.backends[name] = RemoteBackend(
                sub, endpoints[0], table=name, lossy=use_lossy,
                timeout=timeout, retries=retries, backoff=backoff,
                put_window=put_window, pipelined=pipelined)
        else:
            trainer.backends[name] = RemoteShardedBackend(
                sub, endpoints, lossy=use_lossy, table=name,
                timeout=timeout, retries=retries, backoff=backoff,
                put_window=put_window, pipelined=pipelined)
    trainer._needs_prepare = BK.any_requires_prepare(trainer.backends)
    reset_trainer_jit(trainer)
    return trainer.backends


def reset_trainer_jit(trainer):
    """Invalidate the trainer's cached jitted programs — required after a
    membership change: the traced callbacks are bound to the old shard
    set/backend objects."""
    trainer._fused = None
    trainer._eval = None
    trainer._decomposed = None
