"""Multi-process embedding parameter servers (paper §3-§4).

Persia runs NN workers, embedding workers and the embedding PS as separate
services; this package is that tier on one box, with real processes:

* :mod:`repro.net.wire`   — length-prefixed framing + an array-tree codec
  (the checkpoint blob layout on a socket) + the numpy blockscale wire
  format matching the jnp reference bit-for-bit.
* :mod:`repro.net.rpc`    — blocking request/response RPC with per-request
  timeouts, bounded retry/backoff, reconnect, and at-most-once replay
  suppression for mutating ops.
* :mod:`repro.net.ps_server` — the PS process: any ``EmbeddingBackend``
  (dense / host_lru) hosted behind the RPC surface, with a put spool so a
  killed shard loses only its in-flight puts.
* :mod:`repro.net.remote` — the client side: ``RemoteBackend`` implements
  the ``EmbeddingBackend`` protocol over RPC (lookups via
  ``jax.pure_callback``, puts via ordered ``jax.experimental.io_callback``),
  and ``RemoteShardedBackend`` routes a table over k PS processes through
  the same machinery as the in-process ``ShardedBackend``.
* :mod:`repro.net.elastic` — heartbeats, failure detection and live
  elastic membership (a dead shard's logical rows reshard onto survivors
  mid-run, reusing the N->M checkpoint reshard path).
"""

from repro.net.rpc import PSUnavailableError, RpcClient, RpcError, RpcServer
from repro.net.remote import (RemoteBackend, RemoteShardedBackend,
                              connect_remote_backends, reset_trainer_jit)
from repro.net.elastic import (ClusterDeadError, ElasticPSCluster,
                               HeartbeatMonitor, PSMember, is_ps_failure)

__all__ = [
    "PSUnavailableError", "RpcClient", "RpcError", "RpcServer",
    "RemoteBackend", "RemoteShardedBackend", "connect_remote_backends",
    "reset_trainer_jit", "ClusterDeadError", "ElasticPSCluster",
    "HeartbeatMonitor", "PSMember", "is_ps_failure",
]
