"""The embedding-PS process: any in-process ``EmbeddingBackend`` hosted
behind the RPC surface (paper §4.1 — the PS tier as its own service with
its own failure domain).

One server hosts any number of named tables; each table is a *plain*
dense / host_lru backend over the shard's local id space (the sharded
geometry lives client-side in ``RemoteShardedBackend``, exactly as the
in-process router composes plain backends). The server owns the table
state AND its bounded-staleness queue — queued puts are PS-side state, so
killing a shard loses exactly the queue + unacked requests: the paper's
tolerated in-flight loss, and nothing more, because applied puts are
spooled to disk *before* the ack (``--spool-every 1``).

Run one process per shard::

    PYTHONPATH=src python -m repro.net.ps_server --port 0 \
        --port-file /tmp/ps0.port --spool-dir /tmp/ps0.spool

``--port 0`` binds an OS-assigned free port and publishes it through
``--port-file`` (written atomically), so launchers never race on ports.
"""
from __future__ import annotations

import argparse
import os
import shutil
import threading

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import _read_blob, _write_blob
from repro.core import backend as BK
from repro.net import wire
from repro.net.rpc import RpcServer

MUTATING_OPS = frozenset({
    "configure", "init", "seed_rows", "queue_init", "put", "hybrid",
    "restore", "pin", "unpin", "reset_pins",
})


def read_spool(spool_dir: str, table: str):
    """Latest spooled state blob for ``table``, or None if never spooled."""
    root = os.path.join(spool_dir, table)
    cur = os.path.join(root, "CURRENT")
    if not os.path.exists(cur):
        return None
    with open(cur) as f:
        gen = f.read().strip()
    return _read_blob(os.path.join(root, gen))


class PSServer:
    """One PS shard process (or in-process thread, for tests)."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 spool_dir: str | None = None, spool_every: int = 1,
                 reply_delay: float = 0.0):
        self.spool_dir = spool_dir
        self.spool_every = int(spool_every)
        self._tables: dict[str, dict] = {}
        self._lock = threading.RLock()
        self._shutdown = threading.Event()
        handlers = {
            "ping": self._op_ping,
            "configure": self._op_configure,
            "init": self._op_init,
            "seed_rows": self._op_seed_rows,
            "queue_init": self._op_queue_init,
            "prepare": self._op_prepare,
            "lookup": self._op_lookup,
            "read_rows": self._op_read_rows,
            "put": self._op_put,
            "hybrid": self._op_hybrid,
            "pin": self._op_pin,
            "unpin": self._op_unpin,
            "reset_pins": self._op_reset_pins,
            "checkpoint": self._op_checkpoint,
            "restore": self._op_restore,
            "export_logical": self._op_export_logical,
            "metrics": self._op_metrics,
            "shutdown": self._op_shutdown,
            "die": self._op_die,
        }
        self.rpc = RpcServer(handlers, host, port, mutating_ops=MUTATING_OPS,
                             reply_delay=reply_delay)

    @property
    def port(self) -> int:
        return self.rpc.port

    def start(self) -> "PSServer":
        self.rpc.start()
        return self

    def stop(self):
        self._shutdown.set()
        self.rpc.stop()

    def kill(self):
        """Simulate shard death for in-process (threaded) servers: drop all
        table state and stop answering — clients see connection errors,
        exactly as if the process was SIGKILLed. The spool survives."""
        self.stop()
        with self._lock:
            self._tables.clear()

    def wait(self):
        self._shutdown.wait()

    # -- helpers -------------------------------------------------------------

    def _entry(self, table: str) -> dict:
        ent = self._tables.get(table)
        if ent is None:
            raise KeyError(f"table {table!r} not configured on this PS "
                           f"(have {sorted(self._tables)})")
        return ent

    def _maybe_spool(self, table: str, ent: dict, force: bool = False):
        """Persist the applied state BEFORE the op acks, so a killed shard
        loses only unacked/queued puts (never an acknowledged apply)."""
        if self.spool_dir is None or self.spool_every <= 0:
            return
        if not force and ent["puts"] % self.spool_every != 0:
            return
        root = os.path.join(self.spool_dir, table)
        os.makedirs(root, exist_ok=True)
        ent["spool_gen"] = ent.get("spool_gen", 0) + 1
        gen = f"gen_{ent['spool_gen'] % 2}"            # two alternating slots
        d = os.path.join(root, gen)
        if os.path.exists(d):
            shutil.rmtree(d)
        os.makedirs(d)
        _write_blob(d, ent["backend"].state_for_checkpoint(ent["state"]))
        tmp = os.path.join(root, ".current_tmp")
        with open(tmp, "w") as f:
            f.write(gen)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(root, "CURRENT"))

    def _ensure_queue(self, ent: dict, width: int):
        """Lazy queue creation: a hybrid put arriving with no queue (fresh
        configure, or post-restore) creates one at the incoming put width —
        the same dedup-cap width the in-process queue_init derives."""
        if ent["queue"] is None and ent["spec"].staleness > 0 and width > 0:
            ent["queue"] = ent["backend"]._queue_init_width(int(width))

    def _grads_in(self, ent: dict, grads) -> jnp.ndarray:
        return jnp.asarray(wire.lossy_unpack(grads), jnp.float32)

    def _acts_out(self, ent: dict, acts: np.ndarray):
        if ent["lossy"]:
            return wire.lossy_pack(acts, ent["spec"].wire_block)
        return acts

    # -- ops -----------------------------------------------------------------

    def _op_ping(self):
        return {"pid": os.getpid(), "tables": sorted(self._tables)}

    def _op_configure(self, table: str, spec: dict, lossy: bool = False):
        with self._lock:
            s = wire.spec_from_dict(spec)
            base, wrap = BK.parse_backend_name(s.backend)
            if wrap or int(s.emb_shards) != 1:
                raise ValueError(
                    "PSServer hosts plain single-shard backends; the wire "
                    "compression and shard geometry live client-side "
                    f"(got backend={s.backend!r}, emb_shards={s.emb_shards})")
            backend = BK.create_backend(s)
            self._tables[table] = {
                "spec": s, "backend": backend, "state": None, "queue": None,
                "lossy": bool(lossy), "puts": 0,
            }
        return {}

    def _op_init(self, table: str, key, scale: float = 0.02):
        with self._lock:
            ent = self._entry(table)
            ent["state"] = ent["backend"].init(jnp.asarray(key), 1,
                                               float(scale))
            ent["queue"] = None
            self._maybe_spool(table, ent, force=True)
        return {}

    def _op_seed_rows(self, table: str, ids, vecs, accs=None):
        """Seed this shard's local rows (the router's init/reshard path):
        ids are LOCAL row ids, vecs/accs their logical values."""
        with self._lock:
            ent = self._entry(table)
            spec, backend = ent["spec"], ent["backend"]
            ids = np.asarray(ids, np.int64)
            vecs = np.asarray(vecs, np.float32)
            if isinstance(backend, BK.HostLRUBackend):
                ent["state"] = backend._init_with_rows(
                    ids, vecs, None if accs is None
                    else np.asarray(accs, np.float32))
            else:
                vec = np.zeros((spec.rows, spec.dim), np.float32)
                vec[ids] = vecs
                acc = None
                if accs is not None:
                    acc = np.zeros((spec.rows,), np.float32)
                    acc[ids] = np.asarray(accs, np.float32)
                ent["state"] = BK._dense_state_from_logical(
                    spec, spec.rows, vec, acc)
            ent["queue"] = None
            self._maybe_spool(table, ent, force=True)
        return {}

    def _op_queue_init(self, table: str, width: int):
        with self._lock:
            ent = self._entry(table)
            ent["queue"] = None
            if int(width) > 0 and ent["spec"].staleness > 0:
                ent["queue"] = ent["backend"]._queue_init_width(int(width))
        return {}

    def _op_prepare(self, table: str, ids, assume_unique: bool = False):
        with self._lock:
            ent = self._entry(table)
            backend = ent["backend"]
            state, dev = backend.prepare(ent["state"],
                                         np.asarray(ids, np.int64),
                                         bool(assume_unique))
            ent["state"] = state
            return {"dev": np.asarray(dev, np.int32),
                    "faults": int(getattr(backend, "faults", 0)),
                    "hits": int(getattr(backend, "hits", 0))}

    def _op_lookup(self, table: str, dev):
        with self._lock:
            ent = self._entry(table)
            acts, _ = ent["backend"]._lookup_flat(
                ent["state"], jnp.asarray(np.asarray(dev, np.int32)))
            return {"acts": self._acts_out(ent, np.asarray(acts, np.float32))}

    def _op_read_rows(self, table: str, ids):
        """Serve-path read: one atomic RPC resolving logical ids against
        the live state under the server lock (read-only — NOT in
        MUTATING_OPS, so a retried read never perturbs replay
        suppression). A single op replaces the prepare+lookup pair a
        client would otherwise need, closing the window where a
        concurrent trainer fault-in could recycle a slot between the two
        RPCs."""
        with self._lock:
            ent = self._entry(table)
            rows, info = ent["backend"].read_rows(ent["state"],
                                                  np.asarray(ids, np.int64))
            return {"acts": self._acts_out(ent, np.asarray(rows, np.float32)),
                    **info}

    def _op_put(self, table: str, dev, grads, unique: bool = False):
        with self._lock:
            ent = self._entry(table)
            backend = ent["backend"]
            dev_j = jnp.asarray(np.asarray(dev, np.int32))
            g_j = self._grads_in(ent, grads)
            if unique:
                ent["state"], _ = backend._put_unique(ent["state"], dev_j,
                                                      g_j)
            else:
                ent["state"], _ = backend._put_flat(ent["state"], dev_j, g_j)
            ent["puts"] += 1
            self._maybe_spool(table, ent)
        return {}

    def _op_hybrid(self, table: str, dev, grads, unique: bool = False):
        with self._lock:
            ent = self._entry(table)
            backend = ent["backend"]
            dev_j = jnp.asarray(np.asarray(dev, np.int32))
            g_j = self._grads_in(ent, grads)
            self._ensure_queue(ent, int(dev_j.reshape(-1).shape[0]))
            if unique:
                st, q, _ = backend._hybrid_unique(ent["state"], ent["queue"],
                                                  dev_j, g_j)
            else:
                st, q, _ = backend._hybrid_flat(
                    ent["state"], ent["queue"], dev_j,
                    g_j.reshape(-1, ent["spec"].dim))
            ent["state"], ent["queue"] = st, q
            ent["puts"] += 1
            self._maybe_spool(table, ent)
        return {}

    def _op_pin(self, table: str, slots):
        self._entry(table)["backend"].pin_slots(np.asarray(slots, np.int64))
        return {}

    def _op_unpin(self, table: str, slots):
        self._entry(table)["backend"].unpin_slots(np.asarray(slots, np.int64))
        return {}

    def _op_reset_pins(self, table: str):
        self._entry(table)["backend"].reset_pins()
        return {}

    def _op_checkpoint(self, table: str):
        with self._lock:
            ent = self._entry(table)
            return {"blob": ent["backend"].state_for_checkpoint(ent["state"])}

    def _op_restore(self, table: str, blob):
        with self._lock:
            ent = self._entry(table)
            backend = ent["backend"]
            ent["state"] = backend.restore_from_checkpoint(blob)
            # queued puts are addressed in pre-restore geometry: drop them
            # (paper-tolerated in-flight loss); recreated lazily on first put
            ent["queue"] = None
            self._maybe_spool(table, ent, force=True)
            return {"resharded": bool(getattr(backend,
                                              "last_restore_resharded",
                                              False))}

    def _op_export_logical(self, table: str):
        """This shard's rows in local-logical order (the live-reshard
        export): always raw fp32 — reshard must not quantize rows."""
        with self._lock:
            ent = self._entry(table)
            spec, backend = ent["spec"], ent["backend"]
            base, _ = BK.parse_backend_name(spec.backend)
            blob = backend.state_for_checkpoint(ent["state"])
            vec, acc = BK.extract_logical_rows(blob, spec, base)
            return {"vec": np.asarray(vec, np.float32),
                    "acc": None if acc is None
                    else np.asarray(acc, np.float32)}

    def _op_metrics(self, table: str):
        with self._lock:
            ent = self._entry(table)
            backend = ent["backend"]
            return {"puts": ent["puts"],
                    "faults": int(getattr(backend, "faults", 0)),
                    "hits": int(getattr(backend, "hits", 0)),
                    "host_bytes": int(backend.host_bytes())}

    def _op_shutdown(self):
        threading.Timer(0.05, self.stop).start()
        return {}

    def _op_die(self):
        # fault injection for subprocess tests: vanish without a reply
        os._exit(3)


def main(argv=None):
    ap = argparse.ArgumentParser(description="embedding PS shard process")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = OS-assigned (published via --port-file)")
    ap.add_argument("--port-file", default=None,
                    help="write the bound port here (atomic) once listening")
    ap.add_argument("--spool-dir", default=None,
                    help="spool applied state here before acking puts")
    ap.add_argument("--spool-every", type=int, default=1,
                    help="spool every N applied puts (0 = off)")
    ap.add_argument("--reply-delay", type=float, default=0.0,
                    help="delay every reply by this many seconds "
                         "(injected RTT for pipelining benchmarks)")
    args = ap.parse_args(argv)
    server = PSServer(args.host, args.port, spool_dir=args.spool_dir,
                      spool_every=args.spool_every,
                      reply_delay=args.reply_delay).start()
    if args.port_file:
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(server.port))
        os.replace(tmp, args.port_file)
    print(f"ps_server listening on {args.host}:{server.port} "
          f"(pid {os.getpid()})", flush=True)
    server.wait()


if __name__ == "__main__":
    main()
