"""Blocking request/response RPC over the :mod:`repro.net.wire` framing.

Design points (all load-bearing for the PS tier):

* One persistent TCP connection per client, one request in flight at a time
  (the client serializes under a lock — the trainer's put/lookup stream is
  sequential per table anyway; concurrency across *shards* comes from one
  client per shard).
* Per-request timeout + bounded retry with exponential backoff. Retries
  reconnect from scratch, so a dead server surfaces as
  :class:`PSUnavailableError` after the budget — a *named* error the
  elastic layer catches to trigger a membership change.
* Mutating ops carry a ``(client, seq)`` pair; the server remembers each
  client's last applied seq and replays the cached reply instead of
  re-applying — so a retry after a lost reply cannot double-apply a
  gradient put (exactly-once apply over an at-least-once transport).
* A handler exception travels back as :class:`RpcError` with the remote
  type name — the server stays up (bad request != dead shard).
"""
from __future__ import annotations

import socket
import threading
import time
import traceback
import uuid

from repro.net import wire


class RpcError(RuntimeError):
    """The remote handler raised; carries the remote type and message."""


class PSUnavailableError(ConnectionError):
    """A PS endpoint could not be reached within the retry budget."""


class RpcServer:
    """Thread-per-connection frame server dispatching ``op`` to handlers.

    ``handlers`` maps op name -> callable(**args) returning an
    encodable tree. ``mutating_ops`` get at-most-once replay suppression
    keyed on the request's ``(client, seq)``.
    """

    def __init__(self, handlers: dict, host: str = "127.0.0.1",
                 port: int = 0, mutating_ops: set | None = None):
        self.handlers = dict(handlers)
        self.mutating_ops = set(mutating_ops or ())
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(64)
        self.host, self.port = self._listener.getsockname()[:2]
        self._threads: list[threading.Thread] = []
        self._conns: set = set()
        self._conn_lock = threading.Lock()
        self._replay_lock = threading.Lock()
        self._applied: dict[str, tuple[int, bytes]] = {}
        self._stopping = False
        self._accept_thread: threading.Thread | None = None

    def start(self) -> "RpcServer":
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"rpc-accept-{self.port}",
            daemon=True)
        self._accept_thread.start()
        return self

    def stop(self):
        self._stopping = True
        try:
            # closing alone leaves a thread blocked in accept() holding the
            # kernel socket in LISTEN; shutdown wakes it so the port frees
            self._listener.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._listener.close()
        except OSError:
            pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
        with self._conn_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass

    def _accept_loop(self):
        while not self._stopping:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._conn_lock:
                self._conns.add(conn)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name=f"rpc-conn-{self.port}", daemon=True)
            t.start()
            self._threads.append(t)

    def _serve_conn(self, conn: socket.socket):
        try:
            while not self._stopping:
                try:
                    payload = wire.recv_frame(conn)
                except (wire.WireError, OSError):
                    return
                reply = self._dispatch(payload)
                try:
                    wire.send_frame(conn, reply)
                except OSError:
                    return
        finally:
            with self._conn_lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _dispatch(self, payload: bytes) -> bytes:
        try:
            msg = wire.decode(payload)
            op = msg["op"]
            args = msg.get("args") or {}
            seq, client = msg.get("seq"), msg.get("client")
            replay = op in self.mutating_ops and seq is not None \
                and client is not None
            if replay:
                with self._replay_lock:
                    cached = self._applied.get(client)
                if cached is not None and cached[0] == seq:
                    return cached[1]
            handler = self.handlers.get(op)
            if handler is None:
                raise KeyError(f"unknown rpc op {op!r}")
            result = handler(**args)
            reply = wire.encode({"ok": result})
            if replay:
                with self._replay_lock:
                    self._applied[client] = (seq, reply)
            return reply
        except Exception as e:                         # noqa: BLE001
            return wire.encode({
                "err": f"{type(e).__name__}: {e}",
                "tb": traceback.format_exc(limit=8),
            })


class RpcClient:
    """Blocking caller with reconnect + bounded retry/backoff.

    ``call(op, ...)`` raises :class:`RpcError` when the remote handler
    failed (no retry — the server is alive) and
    :class:`PSUnavailableError` when the endpoint cannot be reached /
    answered within ``retries + 1`` attempts.
    """

    def __init__(self, host: str, port: int, timeout: float = 30.0,
                 retries: int = 3, backoff: float = 0.2):
        self.host, self.port = host, int(port)
        self.timeout = float(timeout)
        self.retries = int(retries)
        self.backoff = float(backoff)
        self._sock: socket.socket | None = None
        self._lock = threading.Lock()
        self._client_id = uuid.uuid4().hex
        self._seq = 0
        self.bytes_sent = 0
        self.bytes_recv = 0

    @property
    def endpoint(self) -> tuple[str, int]:
        return (self.host, self.port)

    def _connect(self, timeout: float) -> socket.socket:
        s = socket.create_connection((self.host, self.port), timeout=timeout)
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return s

    def _close_locked(self):
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def close(self):
        with self._lock:
            self._close_locked()

    def call(self, op: str, _mutating: bool = False,
             _timeout: float | None = None, _retries: int | None = None,
             **args):
        timeout = self.timeout if _timeout is None else float(_timeout)
        retries = self.retries if _retries is None else int(_retries)
        with self._lock:
            msg = {"op": op, "args": args}
            if _mutating:
                self._seq += 1
                msg["seq"] = self._seq
                msg["client"] = self._client_id
            payload = wire.encode(msg)
            last_err: Exception | None = None
            for attempt in range(retries + 1):
                if attempt:
                    time.sleep(self.backoff * (2 ** (attempt - 1)))
                try:
                    if self._sock is None:
                        self._sock = self._connect(timeout)
                    self._sock.settimeout(timeout)
                    self.bytes_sent += wire.send_frame(self._sock, payload)
                    reply_raw = wire.recv_frame(self._sock)
                    self.bytes_recv += len(reply_raw) + 12  # + frame header
                except (OSError, wire.WireError) as e:
                    last_err = e
                    self._close_locked()
                    continue
                reply = wire.decode(reply_raw)
                if "err" in reply:
                    raise RpcError(reply["err"])
                return reply["ok"]
            raise PSUnavailableError(
                f"PS at {self.host}:{self.port} unreachable for op {op!r} "
                f"after {retries + 1} attempts: "
                f"{type(last_err).__name__}: {last_err}")

    def ping(self, timeout: float = 1.0, retries: int = 0) -> bool:
        """Liveness probe; False instead of raising on an unreachable PS."""
        try:
            self.call("ping", _timeout=timeout, _retries=retries)
            return True
        except (PSUnavailableError, RpcError):
            return False
