"""Pipelined request/response RPC over rid-tagged :mod:`repro.net.wire`
frames.

Design points (all load-bearing for the PS tier):

* One persistent TCP connection per client, **many requests in flight**:
  every frame carries a transport ``rid``; a per-client io thread demuxes
  replies into the futures ``call_async`` returned. Latency overlaps —
  a window of puts costs ~one RTT, not window RTTs.
* The server executes every op on a connection **serially, in arrival
  order** (ops listed in ``concurrent_ops`` — liveness probes — may
  overtake via a small pool). Client-side send order is the apply order,
  which is what lets the remote backend pipeline puts without draining
  before each prepare.
* Per-request timeout + bounded retry with exponential backoff. A dead
  connection is recovered by the io thread: it reconnects and **resends
  every pending request in rid order**; requests that exhaust their
  budget fail with :class:`PSUnavailableError` — the *named* error the
  elastic layer catches to trigger a membership change.
* Mutating ops carry a ``(client, seq)`` pair; the server keeps a
  **window** of recently applied seqs per client (not just the last one —
  several may be in flight) and replays the cached reply instead of
  re-applying, so a resend after a lost reply cannot double-apply a
  gradient put (exactly-once apply over an at-least-once transport).
* **Op coalescing**: ``coalesce()`` buffers sub-ops client-side and
  ``flush()`` ships them as one ``step_ops`` frame the server unpacks and
  runs in order (one seq — the batch replays as a unit). Any direct call
  flushes the buffer first, so coalescing never reorders against
  non-coalesced traffic.
* A handler exception travels back as :class:`RpcError` with the remote
  type name — the server stays up (bad request != dead shard).
* ``reply_delay`` on the server delays every reply send by a fixed
  interval through a writer thread: the injected-RTT harness the
  benchmarks use to measure pipelining (a blocking client pays the delay
  per op; the pipelined client pays it once per overlapped window).
"""
from __future__ import annotations

import heapq
import select
import socket
import threading
import time
import traceback
import uuid
from collections import OrderedDict
from concurrent.futures import CancelledError, Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutTimeoutError

from repro.net import wire

# ops safe to answer out of order (liveness/introspection only — never
# table state); everything else on a connection runs serially in arrival
# order, which is the ordering contract pipelined puts rely on
CONCURRENT_OPS = frozenset({"ping"})

REPLAY_WINDOW = 1024          # cached replies per client (>= max in-flight)
COALESCE_MAX_OPS = 64         # auto-flush bounds for the step_ops buffer
COALESCE_MAX_BYTES = 8 << 20


class RpcError(RuntimeError):
    """The remote handler raised; carries the remote type and message."""


class PSUnavailableError(ConnectionError):
    """A PS endpoint could not be reached within the retry budget."""


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------

class _ReplyWriter:
    """Per-connection writer thread that sends each reply ``delay``
    seconds after it was produced — the injected-RTT harness. Only exists
    when ``reply_delay > 0``; the zero-delay path sends inline."""

    def __init__(self, conn: socket.socket, delay: float):
        self.conn, self.delay = conn, float(delay)
        self._heap: list = []
        self._n = 0
        self._cond = threading.Condition()
        self._stopping = False
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="rpc-reply-writer")
        self._thread.start()

    def send(self, rid: int, parts: list):
        with self._cond:
            self._n += 1
            heapq.heappush(self._heap,
                           (time.monotonic() + self.delay, self._n, rid,
                            parts))
            self._cond.notify()

    def _run(self):
        while True:
            with self._cond:
                while not self._stopping and not self._heap:
                    self._cond.wait()
                if self._stopping:
                    return
                due = self._heap[0][0]
                now = time.monotonic()
                if now < due:
                    self._cond.wait(timeout=due - now)
                    continue
                _, _, rid, parts = heapq.heappop(self._heap)
            try:
                wire.send_frame_parts(self.conn, rid, parts)
            except (OSError, wire.WireError):
                return

    def stop(self):
        with self._cond:
            self._stopping = True
            self._cond.notify()
        self._thread.join(timeout=5.0)


class RpcServer:
    """Frame server dispatching ``op`` to handlers, one thread per
    connection, ops executed serially in arrival order per connection.

    ``handlers`` maps op name -> callable(**args) returning an encodable
    tree. Requests carrying a ``(client, seq)`` pair (the client attaches
    them to mutating ops) get replay suppression over a window of
    :data:`REPLAY_WINDOW` recent seqs. ``concurrent_ops`` may complete
    out of order (dispatched to a pool). ``reply_delay`` delays every
    reply send by that many seconds (injected RTT for benchmarks).
    """

    def __init__(self, handlers: dict, host: str = "127.0.0.1",
                 port: int = 0, mutating_ops: set | None = None,
                 concurrent_ops: set | None = None,
                 reply_delay: float = 0.0):
        self.handlers = dict(handlers)
        self.mutating_ops = set(mutating_ops or ())
        self.concurrent_ops = set(CONCURRENT_OPS if concurrent_ops is None
                                  else concurrent_ops)
        self.reply_delay = float(reply_delay)
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(64)
        self.host, self.port = self._listener.getsockname()[:2]
        self._threads: list[threading.Thread] = []
        self._conns: set = set()
        self._conn_lock = threading.Lock()
        self._replay_lock = threading.Lock()
        self._applied: dict[str, OrderedDict] = {}
        self._pool: ThreadPoolExecutor | None = None
        self._stopping = False
        self._accept_thread: threading.Thread | None = None
        self.frames_recv = 0

    def start(self) -> "RpcServer":
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"rpc-accept-{self.port}",
            daemon=True)
        self._accept_thread.start()
        return self

    def stop(self):
        self._stopping = True
        try:
            # closing alone leaves a thread blocked in accept() holding the
            # kernel socket in LISTEN; shutdown wakes it so the port frees
            self._listener.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._listener.close()
        except OSError:
            pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
        with self._conn_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        # join the per-connection handler threads too — closing the sockets
        # above unblocks their recv, so repeated start/stop in tests cannot
        # accumulate live threads holding ports/fds
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads = [t for t in self._threads if t.is_alive()]
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None

    def _ensure_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=4, thread_name_prefix=f"rpc-conc-{self.port}")
        return self._pool

    def _accept_loop(self):
        while not self._stopping:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._conn_lock:
                self._conns.add(conn)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name=f"rpc-conn-{self.port}", daemon=True)
            t.start()
            self._threads = [x for x in self._threads if x.is_alive()]
            self._threads.append(t)

    def _serve_conn(self, conn: socket.socket):
        writer = (_ReplyWriter(conn, self.reply_delay)
                  if self.reply_delay > 0 else None)
        send_lock = threading.Lock()

        def reply(rid: int, parts: list):
            try:
                if writer is not None:
                    writer.send(rid, parts)
                else:
                    with send_lock:
                        wire.send_frame_parts(conn, rid, parts)
            except (OSError, wire.WireError):
                pass

        rbuf = wire.RecvBuffer()
        try:
            while not self._stopping:
                try:
                    rid, view = wire.recv_frame_tagged(conn, rbuf)
                except (wire.WireError, OSError):
                    return
                self.frames_recv += 1
                try:
                    msg = wire.decode(view)
                except Exception:                        # noqa: BLE001
                    return          # undecodable request: drop the conn
                if msg.get("op") in self.concurrent_ops:
                    self._ensure_pool().submit(
                        lambda m=msg, r=rid: reply(r, self._dispatch(m)))
                else:
                    reply(rid, self._dispatch(msg))
        finally:
            if writer is not None:
                writer.stop()
            with self._conn_lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _run_handler(self, op: str, args: dict):
        handler = self.handlers.get(op)
        if handler is None:
            raise KeyError(f"unknown rpc op {op!r}")
        return handler(**args)

    def _dispatch(self, msg: dict) -> list:
        """Decoded request -> encoded reply parts. Replay suppression keys
        on the request's ``(client, seq)``: a window of recent seqs per
        client, because a pipelined client may retry any of its in-flight
        seqs (not only the latest) after a lost reply."""
        try:
            op = msg["op"]
            args = msg.get("args") or {}
            seq, client = msg.get("seq"), msg.get("client")
            replay = seq is not None and client is not None
            if replay:
                with self._replay_lock:
                    cache = self._applied.setdefault(client, OrderedDict())
                    cached = cache.get(seq)
                if cached is not None:
                    return cached
            if op == "step_ops":
                result = [self._run_sub(sub) for sub in args["ops"]]
            else:
                result = self._run_handler(op, args)
            parts = wire.encode_parts({"ok": result})
            if replay:
                with self._replay_lock:
                    cache[seq] = parts
                    while len(cache) > REPLAY_WINDOW:
                        cache.popitem(last=False)
            return parts
        except Exception as e:                         # noqa: BLE001
            return wire.encode_parts({
                "err": f"{type(e).__name__}: {e}",
                "tb": traceback.format_exc(limit=8),
            })

    def _run_sub(self, sub: dict) -> dict:
        """One sub-op of a coalesced step_ops batch. A failing sub-op is
        reported in its slot without aborting the rest — sub-ops touch
        independent tables, and the batch (one seq) must leave a
        deterministic replayable reply either way."""
        try:
            return {"ok": self._run_handler(sub["op"],
                                            sub.get("args") or {})}
        except Exception as e:                         # noqa: BLE001
            return {"err": f"{type(e).__name__}: {e}"}


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------

class _Pending:
    __slots__ = ("rid", "parts", "fut", "attempts", "budget", "timeout",
                 "deadline")

    def __init__(self, rid: int, parts: list, fut: Future, attempts: int,
                 timeout: float):
        self.rid, self.parts, self.fut = rid, parts, fut
        self.attempts, self.timeout = attempts, timeout
        self.budget = attempts + 1            # for the error message
        self.deadline: float | None = None    # set when (re)sent


class RpcClient:
    """Pipelined caller with reconnect + bounded retry/backoff.

    ``call_async(op, ...)`` returns a :class:`Future` immediately; many
    may be outstanding on the one connection. ``call`` is the blocking
    wrapper. Futures fail with :class:`RpcError` when the remote handler
    raised (no retry — the server is alive) and
    :class:`PSUnavailableError` when the endpoint cannot be reached /
    answered within ``retries + 1`` attempts. ``coalesce(op, ...)``
    buffers sub-ops for one ``step_ops`` frame; ``flush()`` ships them.
    """

    def __init__(self, host: str, port: int, timeout: float = 30.0,
                 retries: int = 3, backoff: float = 0.2):
        self.host, self.port = host, int(port)
        self.timeout = float(timeout)
        self.retries = int(retries)
        self.backoff = float(backoff)
        self._sock: socket.socket | None = None
        self._cond = threading.Condition()
        self._client_id = uuid.uuid4().hex
        self._rid = 0
        self._pending: dict[int, _Pending] = {}
        self._io_thread: threading.Thread | None = None
        self._closing = False
        self._coal: list[tuple[str, dict, Future]] = []
        self._coal_keys: set = set()
        self._coal_bytes = 0
        self._coal_mutating = False
        self.bytes_sent = 0
        self.bytes_recv = 0
        self.frames_sent = 0
        self.frames_recv = 0

    @property
    def endpoint(self) -> tuple[str, int]:
        return (self.host, self.port)

    # -- connection management (io thread owns recovery) ---------------------

    def _connect(self, timeout: float) -> socket.socket:
        s = socket.create_connection((self.host, self.port), timeout=timeout)
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        s.settimeout(self.timeout)   # mid-frame stall bound; idle uses select
        return s

    def _close_sock_locked(self):
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def close(self):
        with self._cond:
            self._closing = True
            self._close_sock_locked()
            pend = list(self._pending.values())
            self._pending.clear()
            coal = [f for _, _, f in self._coal]
            self._coal, self._coal_keys = [], set()
            self._cond.notify_all()
        err = PSUnavailableError(
            f"client for {self.host}:{self.port} closed")
        for p in pend:
            p.fut.set_exception(err)
        for f in coal:
            f.set_exception(err)
        t = self._io_thread
        if t is not None and t is not threading.current_thread():
            t.join(timeout=5.0)

    def _ensure_io_locked(self):
        if self._io_thread is None or not self._io_thread.is_alive():
            self._io_thread = threading.Thread(
                target=self._io_loop, daemon=True,
                name=f"rpc-io-{self.host}:{self.port}")
            self._io_thread.start()

    def _io_loop(self):
        rbuf = wire.RecvBuffer()
        while True:
            with self._cond:
                while (not self._closing and self._sock is None
                       and not self._pending):
                    self._cond.wait()
                if self._closing:
                    return
                sock = self._sock
            if sock is None:
                self._recover()
                continue
            try:
                readable, _, _ = select.select([sock], [], [], 0.25)
            except (OSError, ValueError):
                readable = None    # socket closed under us
            if readable is None or not readable:
                if readable is None:
                    with self._cond:
                        if self._sock is sock:
                            self._close_sock_locked()
                else:
                    self._check_deadlines()
                continue
            try:
                rid, view = wire.recv_frame_tagged(sock, rbuf)
            except (OSError, wire.WireError):
                with self._cond:
                    if self._sock is sock:
                        self._close_sock_locked()
                continue
            self.bytes_recv += len(view) + wire._HEADER2.size
            self.frames_recv += 1
            try:
                reply = wire.decode(view)
            except Exception:                          # noqa: BLE001
                with self._cond:
                    if self._sock is sock:
                        self._close_sock_locked()
                continue
            with self._cond:
                p = self._pending.pop(rid, None)
            if p is None:
                continue               # late reply for a timed-out request
            if "err" in reply:
                p.fut.set_exception(RpcError(reply["err"]))
            else:
                p.fut.set_result(reply["ok"])

    def _check_deadlines(self):
        now = time.monotonic()
        expired = []
        with self._cond:
            for p in self._pending.values():
                if p.deadline is not None and now > p.deadline:
                    expired.append(p)
            if not expired:
                return
            # a request timed out on a live-looking socket: treat the
            # connection as wedged — recovery reconnects + resends
            self._close_sock_locked()
            failed = self._charge_locked(
                expired, socket.timeout(f"no reply in {expired[0].timeout}s"))
        self._fail(failed)

    def _charge_locked(self, pendings, err) -> list:
        """Charge one attempt to each pending; return the exhausted ones
        (removed from the map) for the caller to fail outside the lock."""
        failed = []
        for p in pendings:
            p.attempts -= 1
            if p.attempts < 0:
                self._pending.pop(p.rid, None)
                failed.append((p, err))
        return failed

    def _fail(self, failed):
        for p, err in failed:
            p.fut.set_exception(PSUnavailableError(
                f"PS at {self.host}:{self.port} unreachable "
                f"after {p.budget} attempts: "
                f"{type(err).__name__}: {err}"))

    def _recover(self):
        """Reconnect with backoff and resend every pending request in rid
        order (send order == apply order; already-applied ones are replay
        -suppressed server-side). Each failed round charges one attempt."""
        round_ = 0
        while True:
            with self._cond:
                if self._closing:
                    return
                if not self._pending:
                    return            # nothing to resend; connect lazily
            if round_:
                time.sleep(min(self.backoff * (2 ** (round_ - 1)), 2.0))
            try:
                sock = self._connect(self.timeout)
            except OSError as e:
                with self._cond:
                    failed = self._charge_locked(
                        list(self._pending.values()), e)
                self._fail(failed)
                round_ += 1
                continue
            with self._cond:
                if self._closing:
                    try:
                        sock.close()
                    except OSError:
                        pass
                    return
                self._sock = sock
                try:
                    for rid in sorted(self._pending):
                        p = self._pending[rid]
                        self._send_locked(p)
                except (OSError, wire.WireError) as e:
                    self._close_sock_locked()
                    failed = self._charge_locked(
                        list(self._pending.values()), e)
                else:
                    return
            self._fail(failed)
            round_ += 1

    def _send_locked(self, p: _Pending):
        n = wire.send_frame_parts(self._sock, p.rid, p.parts)
        self.bytes_sent += n
        self.frames_sent += 1
        p.deadline = time.monotonic() + p.timeout

    # -- request submission --------------------------------------------------

    def _submit_locked(self, msg: dict, mutating: bool,
                       _timeout: float | None,
                       _retries: int | None) -> Future:
        timeout = self.timeout if _timeout is None else float(_timeout)
        retries = self.retries if _retries is None else int(_retries)
        self._rid += 1
        rid = self._rid
        if mutating:
            msg["seq"] = rid
            msg["client"] = self._client_id
        parts = wire.encode_parts(msg)
        fut: Future = Future()
        p = _Pending(rid, parts, fut, retries, timeout)
        self._pending[rid] = p
        self._ensure_io_locked()
        if self._sock is not None:
            try:
                self._send_locked(p)
            except (OSError, wire.WireError):
                self._close_sock_locked()   # io thread recovers + resends
        self._cond.notify_all()
        return fut

    def call_async(self, op: str, _mutating: bool = False,
                   _timeout: float | None = None,
                   _retries: int | None = None, **args) -> Future:
        """Send now, return a Future. Flushes any coalesced buffer first
        so direct traffic never overtakes buffered sub-ops."""
        with self._cond:
            if self._closing:
                raise PSUnavailableError(
                    f"client for {self.host}:{self.port} closed")
            self._flush_locked()
            return self._submit_locked({"op": op, "args": args},
                                       _mutating, _timeout, _retries)

    def call(self, op: str, _mutating: bool = False,
             _timeout: float | None = None, _retries: int | None = None,
             **args):
        fut = self.call_async(op, _mutating, _timeout, _retries, **args)
        return self.result(fut, _timeout, _retries)

    def result(self, fut: Future, _timeout: float | None = None,
               _retries: int | None = None):
        """Await one of this client's futures; the deadline is a safety
        net over the io thread's own timeout/retry machinery."""
        timeout = self.timeout if _timeout is None else float(_timeout)
        retries = self.retries if _retries is None else int(_retries)
        budget = (timeout + 2.5) * (retries + 1) \
            + sum(min(self.backoff * (2 ** k), 2.0) for k in range(retries + 1))
        try:
            return fut.result(timeout=budget)
        except (FutTimeoutError, CancelledError) as e:
            raise PSUnavailableError(
                f"PS at {self.host}:{self.port} gave no reply within "
                f"{budget:.1f}s: {type(e).__name__}") from e

    # -- op coalescing -------------------------------------------------------

    def coalesce(self, op: str, _mutating: bool = False, **args) -> Future:
        """Buffer a sub-op into the next ``step_ops`` frame. The returned
        future resolves when the flushed batch's reply arrives — anything
        that *waits* on it must call :meth:`flush` first (``call`` /
        ``call_async`` flush implicitly). Auto-flushes when the buffer
        holds an op for the same ``(op, table)`` key (per-table streams
        must keep one op per frame in order), or on size caps."""
        key = (op, args.get("table"))
        with self._cond:
            if self._closing:
                raise PSUnavailableError(
                    f"client for {self.host}:{self.port} closed")
            if (key in self._coal_keys
                    or len(self._coal) >= COALESCE_MAX_OPS
                    or self._coal_bytes >= COALESCE_MAX_BYTES):
                self._flush_locked()
            fut: Future = Future()
            self._coal.append((op, args, fut))
            self._coal_keys.add(key)
            self._coal_bytes += wire.tree_nbytes(args)
            self._coal_mutating = self._coal_mutating or _mutating
        return fut

    def flush(self):
        """Ship the coalesced buffer (if any) as one step_ops frame."""
        with self._cond:
            self._flush_locked()

    def _flush_locked(self):
        if not self._coal:
            return
        ops = [{"op": op, "args": args} for op, args, _ in self._coal]
        subs = [f for _, _, f in self._coal]
        mutating = self._coal_mutating
        self._coal, self._coal_keys = [], set()
        self._coal_bytes, self._coal_mutating = 0, False
        batch = self._submit_locked({"op": "step_ops", "args": {"ops": ops}},
                                    mutating, None, None)
        batch.add_done_callback(
            lambda f, subs=subs: _distribute_batch(f, subs))

    def ping(self, timeout: float = 1.0, retries: int = 0) -> bool:
        """Liveness probe; False instead of raising on an unreachable PS."""
        try:
            self.call("ping", _timeout=timeout, _retries=retries)
            return True
        except (PSUnavailableError, RpcError):
            return False


def _distribute_batch(batch: Future, subs: list[Future]):
    """Resolve per-sub-op futures from one step_ops batch reply."""
    err = batch.exception()
    if err is not None:
        for f in subs:
            f.set_exception(err)
        return
    results = batch.result()
    for f, r in zip(subs, results):
        if isinstance(r, dict) and "err" in r:
            f.set_exception(RpcError(r["err"]))
        else:
            f.set_result(r.get("ok") if isinstance(r, dict) else r)
