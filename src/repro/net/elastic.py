"""Elastic PS membership: heartbeats, failure detection, and live
recovery by resharding onto the surviving members (paper §5 — the PS tier
must tolerate shard loss without restarting training).

Failure model (exactly the paper's): a killed shard loses its
bounded-staleness queue and any puts the trainer had not yet had ACKed —
*applied* puts were spooled to disk before their ack (see
``repro.net.ps_server``), so recovery re-seeds the dead shard's rows from
its spool onto the survivors and only tolerated in-flight work is gone.
A dead member with no spool loses its rows to zero-reinit (counted and
reported, never silent).

The recovery loop (:meth:`ElasticPSCluster.step`) has to respect two JAX
realities:

* the failed dispatch may have *donated* the input state's buffers, so the
  dense/optimizer halves are backed up to host numpy before every step and
  restored from there;
* the trainer's cached jitted programs close over the old shard set, so a
  membership change invalidates them (``reset_trainer_jit``) and the next
  step retraces against the new geometry.

A PS failure surfaces from inside a jitted program as a runtime callback
error *wrapping* the transport's :class:`PSUnavailableError` (often only
as text inside an ``XlaRuntimeError``), so :func:`is_ps_failure` matches
the exception chain by name as well as by type.
"""
from __future__ import annotations

import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.net import ps_server, remote
from repro.net.remote import RemoteShardedBackend
from repro.net.rpc import PSUnavailableError, RpcClient


class ClusterDeadError(RuntimeError):
    """No recovery path left: every PS member is gone, or the retry/
    recovery budget is exhausted."""


def is_ps_failure(exc) -> bool:
    """True when ``exc`` (or anything in its cause/context chain) is — or
    wraps — a :class:`PSUnavailableError`. Callback errors cross the XLA
    runtime boundary as flattened text, so the match is by name too."""
    stack, seen = [exc], set()
    while stack:
        e = stack.pop()
        if e is None or id(e) in seen:
            continue
        seen.add(id(e))
        if isinstance(e, PSUnavailableError):
            return True
        if "PSUnavailableError" in f"{type(e).__name__}: {e}":
            return True
        stack.extend((e.__cause__, e.__context__,
                      getattr(e, "original", None)))
    return False


@dataclasses.dataclass
class PSMember:
    """One PS process in the membership: its endpoint, where it spools
    applied state (for post-mortem recovery), and — when the launcher owns
    the process — its handle."""
    host: str
    port: int
    spool_dir: str | None = None
    proc: object = None

    @property
    def endpoint(self) -> tuple[str, int]:
        return (self.host, int(self.port))


def _as_member(m) -> PSMember:
    if isinstance(m, PSMember):
        return m
    return PSMember(*m)


class HeartbeatMonitor:
    """Background liveness prober: pings every member each ``interval``
    seconds (fresh connection, zero retries — a heartbeat must not mask
    death behind the transport's own retry budget) and declares a member
    dead after ``miss_threshold`` consecutive misses."""

    def __init__(self, endpoints, interval: float = 0.5,
                 miss_threshold: int = 2, ping_timeout: float = 0.5):
        self.interval = float(interval)
        self.miss_threshold = int(miss_threshold)
        self.ping_timeout = float(ping_timeout)
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.events: list[dict] = []
        self.reset(endpoints)

    def reset(self, endpoints):
        """Adopt a new membership (post-reshard); history stays in
        ``events``, miss counters and the dead set start over."""
        with self._lock:
            self._endpoints = [tuple(e) for e in endpoints]
            self._misses = {ep: 0 for ep in self._endpoints}
            self.dead: set = set()

    def start(self) -> "HeartbeatMonitor":
        self._thread = threading.Thread(target=self._loop,
                                        name="ps-heartbeat", daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2 * self.interval + 2.0)

    def _loop(self):
        while not self._stop.wait(self.interval):
            self.probe_once()

    def _ping(self, ep) -> bool:
        c = RpcClient(ep[0], ep[1], timeout=self.ping_timeout, retries=0)
        try:
            return c.ping(timeout=self.ping_timeout)
        finally:
            c.close()

    def probe_once(self) -> set:
        """One probe round; returns the (possibly grown) dead set."""
        with self._lock:
            eps = list(self._endpoints)
        for ep in eps:
            ok = self._ping(ep)
            with self._lock:
                if ep not in self._misses:
                    continue                      # reset() raced the probe
                if ok:
                    self._misses[ep] = 0
                elif ep not in self.dead:
                    self._misses[ep] += 1
                    if self._misses[ep] >= self.miss_threshold:
                        self.dead.add(ep)
                        self.events.append({"kind": "dead", "endpoint": ep,
                                            "misses": self._misses[ep]})
        with self._lock:
            return set(self.dead)


class ElasticPSCluster:
    """Trainer-side membership driver: connect tables to the members,
    detect shard death (heartbeats and/or failed steps), reshard the
    survivors live, and keep stepping.

    ``step`` is the resilient entrypoint: it backs the dense half of the
    state up to host memory, runs one trainer step, and on a PS failure
    probes the membership, reshards every table onto the survivors
    (spool blobs standing in for the dead), rebuilds the
    :class:`~repro.core.hybrid.TrainState` and retries — at most
    ``max_recoveries`` times before :class:`ClusterDeadError`."""

    def __init__(self, trainer, members, max_recoveries: int = 2,
                 ping_timeout: float = 1.0):
        self.trainer = trainer
        self.members = [_as_member(m) for m in members]
        if not self.members:
            raise ValueError("ElasticPSCluster needs >= 1 member")
        self.max_recoveries = int(max_recoveries)
        self.ping_timeout = float(ping_timeout)
        self.events: list[dict] = []
        self.monitor: HeartbeatMonitor | None = None
        self._last_backup = None

    # -- membership ----------------------------------------------------------

    def endpoints(self) -> list[tuple[str, int]]:
        return [m.endpoint for m in self.members]

    def connect(self, lossy: bool | None = None, **rpc_opts) -> dict:
        remote.connect_remote_backends(self.trainer, self.endpoints(),
                                       lossy=lossy, **rpc_opts)
        for name, bk in self.trainer.backends.items():
            if not isinstance(bk, RemoteShardedBackend):
                raise TypeError(
                    f"table {name!r}: elastic membership needs sharded "
                    "remote tables — run >= 2 PS members")
        return self.trainer.backends

    def start_heartbeats(self, interval: float = 0.5,
                         miss_threshold: int = 2) -> HeartbeatMonitor:
        self.monitor = HeartbeatMonitor(
            self.endpoints(), interval=interval,
            miss_threshold=miss_threshold,
            ping_timeout=self.ping_timeout).start()
        return self.monitor

    def close(self):
        if self.monitor is not None:
            self.monitor.stop()
        for bk in self.trainer.backends.values():
            if hasattr(bk, "close"):
                bk.close()

    def probe_dead(self) -> list[int]:
        """Synchronous probe of every member; returns dead member indices
        (== shard indices: tables shard in member order)."""
        dead = []
        for i, m in enumerate(self.members):
            c = RpcClient(m.host, m.port, timeout=self.ping_timeout,
                          retries=0)
            try:
                if not c.ping(timeout=self.ping_timeout):
                    dead.append(i)
            finally:
                c.close()
        return dead

    # -- state plumbing ------------------------------------------------------

    def _backup(self, state):
        """Host copy of the non-PS half of the state — the failed dispatch
        may have donated the originals.

        A put dispatched by the *previous* step can fail asynchronously
        after that step already returned (the paper's tolerated in-flight
        loss); the XLA error then poisons the returned state's buffers,
        including leaves no put writes. Poisoned leaves fall back to the
        last good host copy leaf-wise: the dense halves were updated by
        their own (successful) dispatch and usually re-read fine, and the
        step counter — defined alongside the failed put — advances by
        exactly one over the copy captured before that step ran."""
        src = (state.dense, state.opt, state.dense_queue, state.step)
        try:
            out = jax.tree.map(lambda x: np.array(x, copy=True), src)
        except Exception as e:                         # noqa: BLE001
            if not is_ps_failure(e) or self._last_backup is None:
                raise
            fb_dense, fb_opt, fb_dq, fb_step = self._last_backup

            def leaf(x, fb):
                try:
                    return np.array(x, copy=True)
                except Exception as le:                # noqa: BLE001
                    if not is_ps_failure(le):
                        raise
                    return np.array(fb, copy=True)

            halves = jax.tree.map(
                leaf, (state.dense, state.opt, state.dense_queue),
                (fb_dense, fb_opt, fb_dq))
            try:
                step = np.array(state.step, copy=True)
            except Exception as le:                    # noqa: BLE001
                if not is_ps_failure(le):
                    raise
                fb_step = np.asarray(fb_step)
                step = (fb_step + 1).astype(fb_step.dtype)
            out = (*halves, step)
        self._last_backup = out
        return out

    def _restate(self, backup, emb, emb_queue):
        from repro.core.hybrid import TrainState
        dense, opt, dq, step = jax.tree.map(jnp.asarray, backup)
        return TrainState(dense=dense, opt=opt, emb=emb,
                          emb_queue=emb_queue, dense_queue=dq, step=step)

    def _fresh_emb(self):
        """Fresh version scalars + reset queues for the *current* shard
        set — the transient-failure rebuild (PS state itself is intact,
        only the client-side pytree was lost to donation)."""
        emb, eq = {}, {}
        for name, bk in self.trainer.backends.items():
            # outstanding window acks reference the failed dispatch; drop
            # them so the retry doesn't re-raise a stale transport error
            bk.discard_pending()
            emb[name] = {f"s{s}": sub._fresh_state()
                         for s, sub in enumerate(bk.shard_backends)}
            eq[name] = (bk._queue_init_width(bk._queue_width_cfg)
                        if bk.spec.staleness > 0 else None)
        return emb, eq

    # -- recovery ------------------------------------------------------------

    def recover(self, backup, dead: list[int]):
        """Reshard every table onto the members surviving ``dead`` (their
        spools standing in for the dead shards' rows) and rebuild the
        train state. Pending PS queues restart empty — the paper's
        tolerated in-flight loss."""
        dead = sorted(set(dead))
        survivors = [m for i, m in enumerate(self.members) if i not in dead]
        if not survivors:
            raise ClusterDeadError(
                f"all {len(self.members)} PS members are dead")
        emb, eq, lost = {}, {}, {}
        for name, bk in self.trainer.backends.items():
            # discard the table's outstanding-ack window before resharding:
            # unacked in-flight puts were addressed to the old geometry
            # (possibly the dead shard) — the paper's tolerated loss, not
            # an error to surface mid-recovery. Acked puts were spooled
            # server-side before their ack, so nothing acked is lost.
            bk.discard_pending()
            blobs = {}
            for i in dead:
                sd = self.members[i].spool_dir
                if sd is not None:
                    try:
                        blobs[i] = ps_server.read_spool(sd, name)
                    except (OSError, ValueError, KeyError):
                        blobs[i] = None             # corrupt spool == no spool
            emb[name], eq[name] = bk.reshard_live(
                [m.endpoint for m in survivors], blobs)
            lost[name] = int(bk.last_reshard_lost_rows)
        self.members = survivors
        if self.monitor is not None:
            self.monitor.reset(self.endpoints())
        remote.reset_trainer_jit(self.trainer)
        self.events.append({"kind": "reshard", "dead": dead,
                            "k": len(survivors), "lost_rows": lost})
        return self._restate(backup, emb, eq)

    def join(self, member, state):
        """Grow the membership: reshard every table onto members + the
        new one (live N -> N+1) and return the rebuilt state."""
        m = _as_member(member)
        backup = self._backup(state)
        new_members = self.members + [m]
        emb, eq = {}, {}
        for name, bk in self.trainer.backends.items():
            # planned membership change, every member alive: DRAIN the
            # outstanding-ack window (don't discard) so no buffered put is
            # lost to the export — falling back to discard only if a member
            # died under us (then recover() owns the cleanup anyway)
            try:
                bk.sync(state.emb[name])
            except Exception:                          # noqa: BLE001
                bk.discard_pending()
            emb[name], eq[name] = bk.reshard_live(
                [mm.endpoint for mm in new_members], None)
        self.members = new_members
        if self.monitor is not None:
            self.monitor.reset(self.endpoints())
        remote.reset_trainer_jit(self.trainer)
        self.events.append({"kind": "join", "endpoint": m.endpoint,
                            "k": len(new_members)})
        return self._restate(backup, emb, eq)

    # -- the resilient step loop ---------------------------------------------

    def step(self, state, batch, step_fn=None):
        """One trainer step that survives shard death. ``step_fn`` defaults
        to the trainer's ``decomposed_step``; anything with the
        ``(state, batch) -> (state, metrics)`` shape works."""
        fn = step_fn if step_fn is not None else self.trainer.decomposed_step
        last: Exception | None = None
        for attempt in range(self.max_recoveries + 1):
            backup = self._backup(state)
            try:
                out = fn(state, batch)
                # the put callbacks dispatch asynchronously; block so a
                # failure surfaces HERE (classified, recoverable) instead
                # of poisoning buffers consumed after we report success
                return jax.block_until_ready(out)
            except Exception as e:                     # noqa: BLE001
                if not is_ps_failure(e):
                    raise
                last = e
                if attempt == self.max_recoveries:
                    break
                dead = self.probe_dead()
                if dead:
                    state = self.recover(backup, dead)
                else:
                    # transient (timeout blip): the membership is intact,
                    # rebuild the donated pytree and retry the step
                    self.events.append({"kind": "transient"})
                    emb, eq = self._fresh_emb()
                    state = self._restate(backup, emb, eq)
        raise ClusterDeadError(
            f"PS failure persisted through {self.max_recoveries} "
            f"recoveries") from last
