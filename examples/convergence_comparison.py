"""Reproduce the paper's §6.2 comparison (Fig 7 / Table 2 shape): hybrid vs
fully-sync vs fully-async on a synthetic CTR task. Expect: hybrid ~ sync,
async worse. Writes a CSV of AUC curves.

  PYTHONPATH=src python examples/convergence_comparison.py
"""
import csv

from benchmarks.convergence import DATASETS, train_mode
from repro.core.hybrid import TrainMode

MODES = {"sync": TrainMode.sync(),
         "hybrid": TrainMode.hybrid(4),
         "async": TrainMode.async_(8, 8)}

ds = DATASETS["taobao"]
curves = {}
for name, mode in MODES.items():
    auc, wall, points = train_mode(ds, mode, steps=200, curve=True)
    curves[name] = points
    print(f"{name:8s} final AUC {auc:.4f}  ({wall:.1f}s)")

with open("convergence_curves.csv", "w", newline="") as f:
    w = csv.writer(f)
    w.writerow(["step"] + list(MODES))
    for i in range(len(curves["sync"])):
        w.writerow([curves["sync"][i][0]]
                   + [f"{curves[m][i][1]:.4f}" for m in MODES])
print("wrote convergence_curves.csv")

gap_h = curves["sync"][-1][1] - curves["hybrid"][-1][1]
gap_a = curves["sync"][-1][1] - curves["async"][-1][1]
print(f"sync-hybrid gap {gap_h:+.4f} (paper: <0.001); "
      f"sync-async gap {gap_a:+.4f} (paper: 0.005..0.01)")
