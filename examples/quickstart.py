"""Quickstart: train a CTR recommender with Persia's hybrid algorithm in
~30 lines. Embedding tables live in the sharded PS and update asynchronously
(bounded staleness tau=3); the dense FFNN updates synchronously.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import adapters, embedding_ps as PS, hybrid
from repro.core.hybrid import TrainMode
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig, make_optimizer

# 1. a dataset (synthetic Taobao-shaped CTR stream) and a matching model
ds = CTRDataset("demo", n_rows=20_000, n_fields=8, ids_per_field=4, n_dense=8)
cfg = ModelConfig(name="demo-dlrm", arch_type="recsys", n_id_fields=8,
                  ids_per_field=4, emb_dim=32, emb_rows=20_000,
                  n_dense_features=8, mlp_dims=(256, 128, 64))

# 2. the hybrid trainer: async embeddings (tau=3), sync dense
adapter = adapters.recsys_adapter(cfg, lr=5e-2)
opt_init, opt_update = make_optimizer(OptConfig(kind="adam", lr=5e-3))
mode = TrainMode.hybrid(tau=3)
stream = ds.sampler(batch_size=512)
batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
state, spec = hybrid.init_train_state(adapter, mode, opt_init,
                                      jax.random.PRNGKey(0), batch)
step = jax.jit(hybrid.make_train_step(adapter, spec, mode, opt_update),
               donate_argnums=(0,))

# 3. train + evaluate AUC
for i in range(150):
    batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
    state, metrics = step(state, batch)
    if (i + 1) % 30 == 0:
        eval_b = {k: jnp.asarray(v) for k, v in next(stream).items()}
        acts = PS.lookup(state["emb"], spec, eval_b["ids"])
        preds = adapter.predict(state["dense"], acts, eval_b)
        auc = adapters.auc(np.asarray(eval_b["labels"]), np.asarray(preds))
        print(f"step {i+1:4d}  loss {float(metrics['loss']):.4f}  "
              f"AUC {auc:.4f}")

print("done — the embedding PS held", state["emb"]["table"].shape[0],
      "rows; dense params:",
      sum(x.size for x in jax.tree.leaves(state["dense"])))
