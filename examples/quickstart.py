"""Quickstart: train a CTR recommender with Persia's hybrid algorithm in
~25 lines. Each ID feature field gets its own embedding table in the
sharded PS (an EmbeddingCollection) and updates asynchronously (bounded
staleness tau=3); the dense FFNN updates synchronously. The PersiaTrainer
facade owns the whole loop: init, fused step, eval, checkpointing.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig

# 1. a dataset (synthetic Taobao-shaped CTR stream) and a matching model
ds = CTRDataset("demo", n_rows=20_000, n_fields=8, ids_per_field=4, n_dense=8)
cfg = ModelConfig(name="demo-dlrm", arch_type="recsys", n_id_fields=8,
                  ids_per_field=4, emb_dim=32, emb_rows=20_000,
                  n_dense_features=8, mlp_dims=(256, 128, 64))

# 2. the hybrid trainer: one embedding table per ID field (async, tau=3),
#    sync dense — all behind one facade
adapter = adapters.recsys_adapter(cfg, lr=5e-2, field_rows=ds.field_rows())
trainer = PersiaTrainer(adapter, TrainMode.hybrid(tau=3),
                        OptConfig(kind="adam", lr=5e-3))
stream = ds.sampler(batch_size=512)
batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
state = trainer.init(jax.random.PRNGKey(0), batch)

# 3. train + evaluate AUC
for i in range(150):
    batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
    state, metrics = trainer.step(state, batch)
    if (i + 1) % 30 == 0:
        eval_b = {k: jnp.asarray(v) for k, v in next(stream).items()}
        preds = trainer.predict(state, eval_b)
        auc = adapters.auc(np.asarray(eval_b["labels"]), np.asarray(preds))
        print(f"step {i+1:4d}  loss {float(metrics['loss']):.4f}  "
              f"AUC {auc:.4f}")

rows = sum(st["table"].shape[0] for st in state.emb.values())
print(f"done — the embedding PS held {len(state.emb)} tables "
      f"({rows} rows); dense params:",
      sum(x.size for x in jax.tree.leaves(state.dense)))
