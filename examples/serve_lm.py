"""Batched serving example: prefill + token-by-token decode with per-layer
KV caches on a reduced assigned architecture (pick any of the 10).

  PYTHONPATH=src python examples/serve_lm.py --arch jamba_v0_1_52b
"""
import argparse

from repro.configs import ARCH_IDS, get_config
from repro.launch.serve import serve

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="granite_3_2b", choices=ARCH_IDS)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--gen", type=int, default=16)
args = ap.parse_args()

cfg = get_config(args.arch, reduced=True)
print(f"serving reduced {cfg.name}: {cfg.n_layers} layers, "
      f"d_model={cfg.d_model}")
res = serve(cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen)
print(f"prefill {res['prefill_s']:.2f}s; decode {res['decode_s']:.2f}s "
      f"= {res['decode_tok_per_s']:.1f} tok/s")
print("sample 0 generated ids:", res["tokens"][0])
