"""End-to-end driver: train a ~100M-parameter recommender (the paper's model
shape — embedding-dominated, 96M embedding + 12M dense FFNN) for a few
hundred steps with the hybrid algorithm, with checkpointing and eval.

  PYTHONPATH=src python examples/train_dlrm_100m.py [--steps 300]
"""
import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.checkpoint import CheckpointManager, load_checkpoint
from repro.core import adapters, embedding_ps as PS, hybrid
from repro.core.hybrid import TrainMode
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig, make_optimizer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=1024)
ap.add_argument("--ckpt", default="/tmp/persia_dlrm_ckpt")
args = ap.parse_args()

ROWS = 750_000          # x 128 dim = 96M embedding params
cfg = ModelConfig(name="dlrm-100m", arch_type="recsys", n_id_fields=26,
                  ids_per_field=2, emb_dim=128, emb_rows=ROWS,
                  n_dense_features=13,
                  mlp_dims=(1024, 512, 256, 128),   # ~12M dense
                  emb_staleness=3)
ds = CTRDataset("criteo100m", n_rows=ROWS, n_fields=26, ids_per_field=2,
                n_dense=13)

adapter = adapters.recsys_adapter(cfg, lr=5e-2)
opt_init, opt_update = make_optimizer(OptConfig(kind="adam", lr=3e-3))
mode = TrainMode.hybrid(3)
stream = ds.sampler(args.batch)
batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
state, spec = hybrid.init_train_state(adapter, mode, opt_init,
                                      jax.random.PRNGKey(0), batch)
emb_params = state["emb"]["table"].size
dense_params = sum(x.size for x in jax.tree.leaves(state["dense"]))
print(f"embedding params: {emb_params/1e6:.1f}M   "
      f"dense params: {dense_params/1e6:.1f}M   "
      f"total {(emb_params+dense_params)/1e6:.1f}M")

# decomposed pipeline: in-place PS puts, separate dispatches (runtime path)
fns = hybrid.make_decomposed_fns(adapter, spec, mode, opt_update)
mgr = CheckpointManager(args.ckpt, every=100, keep=2)

import time
t0 = time.time()
for i in range(args.steps):
    batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
    state, metrics = hybrid.decomposed_train_step(fns, state, batch, adapter)
    if (i + 1) % 50 == 0:
        eval_b = {k: jnp.asarray(v) for k, v in next(stream).items()}
        acts = fns[0](state["emb"], eval_b["ids"])
        preds = adapter.predict(state["dense"], acts, eval_b)
        auc = adapters.auc(np.asarray(eval_b["labels"]), np.asarray(preds))
        thr = (i + 1) * args.batch / (time.time() - t0)
        print(f"step {i+1:4d}  loss {float(metrics['loss']):.4f}  "
              f"AUC {auc:.4f}  {thr:,.0f} samples/s")
    mgr.maybe_save(i + 1, state["dense"], {"table": state["emb"]["table"],
                                           "acc": state["emb"]["acc"]})

step_no, dense, emb = load_checkpoint(args.ckpt)
print(f"checkpoint roundtrip ok (step {step_no}); "
      f"fault-tolerance policy: dense atomic, emb shards independent")
