"""End-to-end driver: train a ~100M-parameter recommender (the paper's model
shape — embedding-dominated, 96M embedding + 12M dense FFNN) for a few
hundred steps with the hybrid algorithm, with full-state checkpointing and
eval. The 26 ID fields each own a table in the EmbeddingCollection; the
decomposed (3-dispatch, donated) pipeline is the runtime-faithful path.

  PYTHONPATH=src python examples/train_dlrm_100m.py [--steps 300]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.checkpoint import CheckpointManager
from repro.core import adapters
from repro.core.hybrid import PersiaTrainer, TrainMode
from repro.data.ctr import CTRDataset
from repro.optim.optimizers import OptConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=1024)
ap.add_argument("--ckpt", default="/tmp/persia_dlrm_ckpt")
args = ap.parse_args()

ROWS = 750_000          # x 128 dim = 96M embedding params
cfg = ModelConfig(name="dlrm-100m", arch_type="recsys", n_id_fields=26,
                  ids_per_field=2, emb_dim=128, emb_rows=ROWS,
                  n_dense_features=13,
                  mlp_dims=(1024, 512, 256, 128),   # ~12M dense
                  emb_staleness=3)
ds = CTRDataset("criteo100m", n_rows=ROWS, n_fields=26, ids_per_field=2,
                n_dense=13)

adapter = adapters.recsys_adapter(cfg, lr=5e-2, field_rows=ds.field_rows())
trainer = PersiaTrainer(adapter, TrainMode.hybrid(3),
                        OptConfig(kind="adam", lr=3e-3))
stream = ds.sampler(args.batch)
batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
state = trainer.init(jax.random.PRNGKey(0), batch)
emb_params = sum(st["table"].size for st in state.emb.values())
dense_params = sum(x.size for x in jax.tree.leaves(state.dense))
print(f"embedding params: {emb_params/1e6:.1f}M over {len(state.emb)} "
      f"tables   dense params: {dense_params/1e6:.1f}M   "
      f"total {(emb_params+dense_params)/1e6:.1f}M")

# decomposed pipeline: in-place PS puts, separate dispatches (runtime path)
mgr = CheckpointManager(args.ckpt, every=100, keep=2)

import time
t0 = time.time()
saved = None
for i in range(args.steps):
    batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
    state, metrics = trainer.decomposed_step(state, batch)
    if (i + 1) % 50 == 0:
        eval_b = {k: jnp.asarray(v) for k, v in next(stream).items()}
        preds = trainer.predict(state, eval_b)
        auc = adapters.auc(np.asarray(eval_b["labels"]), np.asarray(preds))
        thr = (i + 1) * args.batch / (time.time() - t0)
        print(f"step {i+1:4d}  loss {float(metrics['loss']):.4f}  "
              f"AUC {auc:.4f}  {thr:,.0f} samples/s")
    # full state: dense + opt moments + tables + adagrad acc + queues
    saved = mgr.maybe_save_state(i + 1, trainer, state)

if saved is None:                       # final step wasn't on the interval
    trainer.save(args.ckpt, state)
restored = trainer.restore(args.ckpt)
np.testing.assert_array_equal(
    np.asarray(state.emb["field_00"]["acc"]),
    np.asarray(restored.emb["field_00"]["acc"]))
print(f"checkpoint roundtrip ok (step {int(restored.step)}, adagrad acc "
      f"intact); fault-tolerance policy: dense atomic, emb shards "
      f"independent")
